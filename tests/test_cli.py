"""cuthermo CLI: --help via subprocess, subcommand flows in-process."""

import os
import subprocess
import sys

import pytest

from repro import cli

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.abspath(REPO_SRC)
        + os.pathsep
        + env.get("PYTHONPATH", "")
    )
    return subprocess.run(
        [sys.executable, "-m", "repro.cli", *args],
        capture_output=True,
        text=True,
        env=env,
        timeout=120,
    )


# -- subprocess: the console entry point actually runs ----------------------


def test_help_subprocess():
    proc = _run_cli("--help")
    assert proc.returncode == 0
    out = proc.stdout
    for sub in ("profile", "model", "report", "diff", "check", "kernels",
                "tune"):
        assert sub in out


@pytest.mark.parametrize("sub", ["profile", "model", "report", "diff",
                                 "check", "kernels", "tune"])
def test_subcommand_help_subprocess(sub):
    proc = _run_cli(sub, "--help")
    assert proc.returncode == 0
    assert "usage" in proc.stdout.lower()


def test_no_command_prints_help():
    proc = _run_cli()
    assert proc.returncode == 2


# -- subprocess: the 0/1/2 exit-code contract of the CI gates ---------------


@pytest.fixture(scope="module")
def gate_session(tmp_path_factory):
    """Two profiled iterations: iter0 the tiled gemm, iter1 the naive."""
    sess = str(tmp_path_factory.mktemp("gate") / "sess")
    assert cli.main(["profile", "--kernel", "gemm:v01", "--out", sess,
                     "--quiet"]) == 0
    assert cli.main(["profile", "--kernel", "gemm:v00", "--out", sess,
                     "--quiet"]) == 0
    return sess


def test_diff_exit_code_contract_subprocess(gate_session, tmp_path):
    good, bad = (os.path.join(gate_session, "iter0"),
                 os.path.join(gate_session, "iter1"))
    # 0: no regression (self-diff)
    assert _run_cli("diff", good, good,
                    "--fail-on-regression").returncode == 0
    # 1: a real regression under --fail-on-regression
    assert _run_cli("diff", good, bad,
                    "--fail-on-regression").returncode == 1
    # 2: missing artifact — a LOAD error, not a gate verdict
    proc = _run_cli("diff", good, os.path.join(gate_session, "nope"),
                    "--fail-on-regression")
    assert proc.returncode == 2
    assert "manifest" in proc.stderr
    # 2: malformed manifest (entry missing its npz key) — previously an
    # uncaught KeyError, which Python exits 1 on, indistinguishable
    # from a regression verdict
    broken = tmp_path / "broken"
    broken.mkdir()
    (broken / "manifest.json").write_text(
        '{"format": "cuthermo-iteration", "version": 4, '
        '"label": "broken", "created": 0.0, '
        '"kernels": [{"name": "gemm"}]}'
    )
    proc = _run_cli("diff", good, str(broken), "--fail-on-regression")
    assert proc.returncode == 2
    assert "Traceback" not in proc.stderr
    # 2: bad --region-map spec (usage error)
    assert _run_cli("diff", good, good,
                    "--region-map", "nocolon").returncode == 2


def test_check_exit_code_contract_subprocess(gate_session, tmp_path):
    import json

    good, bad = (os.path.join(gate_session, "iter0"),
                 os.path.join(gate_session, "iter1"))
    # 0: candidate matches baseline
    assert _run_cli("check", good, "--baseline", good).returncode == 0
    # 1: gate failure, with the machine-readable report on stdout
    proc = _run_cli("check", bad, "--baseline", good, "--json", "-",
                    "--quiet")
    assert proc.returncode == 1
    doc = json.loads(proc.stdout)
    assert doc["format"] == "cuthermo-check"
    assert doc["schema_version"] == 1
    assert doc["passed"] is False
    # 2: usage and load errors never masquerade as gate failures
    assert _run_cli("check", good).returncode == 2
    assert _run_cli("check", str(tmp_path / "nope"),
                    "--baseline", good).returncode == 2
    assert _run_cli("check", good, "--baseline", good,
                    "--threshold", "bogus=1").returncode == 2


# -- in-process: profile -> diff -> report ----------------------------------


def test_profile_diff_report_flow(tmp_path, capsys):
    sess = str(tmp_path / "sess")
    assert cli.main(["profile", "--kernel", "gemm", "--out", sess,
                     "--quiet"]) == 0
    assert cli.main(["profile", "--kernel", "gemm:v01", "--out", sess,
                     "--quiet"]) == 0
    capsys.readouterr()

    assert cli.main(["diff", os.path.join(sess, "iter0"),
                     os.path.join(sess, "iter1")]) == 0
    out = capsys.readouterr().out
    assert "improved" in out and "gemm" in out
    assert "false-sharing" in out

    # regression gating: the reversed diff fails with --fail-on-regression
    assert cli.main(["diff", os.path.join(sess, "iter1"),
                     os.path.join(sess, "iter0"),
                     "--fail-on-regression"]) == 1

    assert cli.main(["report", os.path.join(sess, "iter1")]) == 0
    report_dir = tmp_path / "sess" / "iter1" / "report"
    index = report_dir / "index.html"
    assert index.is_file() and (report_dir / "report.md").is_file()
    html = index.read_text()
    assert "gemm" in html and "<table>" in html

    # report on the session root uses the latest iteration
    assert cli.main(["report", sess, "--out", str(tmp_path / "r2")]) == 0
    assert (tmp_path / "r2" / "index.html").is_file()


def test_profile_writes_versioned_artifacts(tmp_path):
    from repro.core.session import ARTIFACT_VERSION, load_iteration

    sess = str(tmp_path / "sess")
    assert cli.main(["profile", "--kernel", "ttm", "--out", sess,
                     "--quiet", "--label", "baseline"]) == 0
    it = load_iteration(os.path.join(sess, "iter0"))
    assert it.label == "baseline"
    assert it.kernel("ttm").variant == "scratch"
    import json

    manifest = json.loads(
        (tmp_path / "sess" / "iter0" / "manifest.json").read_text()
    )
    assert manifest["version"] == ARTIFACT_VERSION


def test_profile_workers_matches_serial(tmp_path, capsys):
    """--workers 2 collects through the shard pool; the stored heat map
    is bit-identical to the serial run and carries shard provenance."""
    from repro.core.session import heatmaps_equal, load_iteration

    sess = str(tmp_path / "sess")
    assert cli.main(["profile", "--kernel", "ttm", "--out", sess,
                     "--quiet"]) == 0
    assert cli.main(["profile", "--kernel", "ttm", "--out", sess,
                     "--workers", "2"]) == 0
    out = capsys.readouterr().out
    assert "collected in 2 shards" in out
    serial = load_iteration(os.path.join(sess, "iter0")).kernel("ttm")
    sharded = load_iteration(os.path.join(sess, "iter1")).kernel("ttm")
    assert serial.shards == () and len(sharded.shards) == 2
    assert heatmaps_equal(serial.heatmap, sharded.heatmap)


def test_region_map_automatic_from_registry(tmp_path, capsys):
    # the registry knows gramschm's optimization renames q -> qT; the
    # stored rename makes the diff align without any --region-map flag
    sess = str(tmp_path / "sess")
    assert cli.main(["profile", "--kernel", "gramschm", "--out", sess,
                     "--quiet"]) == 0
    assert cli.main(["profile", "--kernel", "gramschm:opt", "--out", sess,
                     "--quiet"]) == 0
    capsys.readouterr()
    assert cli.main(["diff", os.path.join(sess, "iter0"),
                     os.path.join(sess, "iter1")]) == 0
    out = capsys.readouterr().out
    assert "strided" in out and "fixed" in out

    # the explicit flag still works as an override
    assert cli.main(["diff", os.path.join(sess, "iter0"),
                     os.path.join(sess, "iter1"),
                     "--region-map", "gramschm:q=qT"]) == 0
    assert "strided" in capsys.readouterr().out

    # self-diff of either side: the stored rename must be a no-op
    capsys.readouterr()
    assert cli.main(["diff", os.path.join(sess, "iter0"),
                     os.path.join(sess, "iter0")]) == 0
    assert "unchanged" in capsys.readouterr().out
    assert cli.main(["diff", os.path.join(sess, "iter1"),
                     os.path.join(sess, "iter1")]) == 0
    assert "unchanged" in capsys.readouterr().out


def test_unknown_kernel_fails(tmp_path, capsys):
    rc = cli.main(["profile", "--kernel", "nope", "--out",
                   str(tmp_path / "s"), "--quiet"])
    assert rc == 2
    assert "unknown kernel" in capsys.readouterr().err


# -- in-process: tune --------------------------------------------------------


def test_tune_closes_the_loop(tmp_path, capsys):
    from repro.core.session import load_iteration

    sess = str(tmp_path / "sess")
    assert cli.main(["tune", "gemm", "--budget", "2", "--out", sess,
                     "--quiet"]) == 0
    out = capsys.readouterr().out
    assert "tune: gemm" in out and "accepted" in out
    assert "1 improved" in out
    # trajectory persisted: baseline + up to 2 candidate iterations,
    # each with tuning provenance in its manifest
    it0 = load_iteration(os.path.join(sess, "iter0"))
    assert it0.tuning["role"] == "baseline"
    it1 = load_iteration(os.path.join(sess, "iter1"))
    assert it1.tuning["candidate"]["label"].startswith("ladder:")


def test_tune_report_bundle_has_trajectory(tmp_path, capsys):
    sess = str(tmp_path / "sess")
    assert cli.main(["tune", "gramschm", "--budget", "2", "--out", sess,
                     "--quiet", "--report"]) == 0
    capsys.readouterr()
    index = tmp_path / "sess" / "report" / "index.html"
    assert index.is_file()
    html = index.read_text()
    assert "tuning trajectory" in html and "ladder:opt" in html
    md = (tmp_path / "sess" / "report" / "report.md").read_text()
    assert "tuning trajectory" in md


def test_report_on_tuned_session_recovers_trajectory(tmp_path, capsys):
    sess = str(tmp_path / "sess")
    assert cli.main(["tune", "ttm", "--budget", "1", "--out", sess,
                     "--quiet"]) == 0
    capsys.readouterr()
    # report pointed at the SESSION ROOT rebuilds the trajectory from
    # the stored v3 provenance alone
    assert cli.main(["report", sess, "--out", str(tmp_path / "r")]) == 0
    html = (tmp_path / "r" / "index.html").read_text()
    assert "tuning trajectory" in html


def test_report_on_tuned_session_renders_best_not_last(tmp_path, capsys):
    # gramschm budget 2: step 1 (ladder:opt) accepted, step 2 (pin)
    # rejected — the LAST iteration is the rejected candidate, but the
    # report body must show the winning variant
    sess = str(tmp_path / "sess")
    assert cli.main(["tune", "gramschm", "--budget", "2", "--out", sess,
                     "--quiet"]) == 0
    capsys.readouterr()
    assert cli.main(["report", sess, "--out", str(tmp_path / "r")]) == 0
    html = (tmp_path / "r" / "index.html").read_text()
    assert "gramschmidt_kernel3_opt" in html  # the best variant's kernel
    assert "+pin" not in html  # the rejected candidate's spec is not the body
    assert "(tuned)" in html


def test_tune_target_pattern_and_seed_flags(tmp_path, capsys):
    sess = str(tmp_path / "sess")
    assert cli.main(["tune", "gemm", "--budget", "1", "--out", sess,
                     "--target-pattern", "false-sharing",
                     "--seed", "7", "--quiet"]) == 0
    out = capsys.readouterr().out
    assert "false-sharing" in out


def test_tune_unknown_kernel_fails(tmp_path, capsys):
    rc = cli.main(["tune", "nope", "--out", str(tmp_path / "s"),
                   "--quiet"])
    assert rc == 2
    assert "unknown kernel" in capsys.readouterr().err


def test_tune_target_pattern_choices_match_detectors(tmp_path, capsys):
    # the parser inlines the vocabulary (so --help stays numpy-free);
    # it must not drift from the detectors' canonical list
    from repro.core.patterns import ALL_PATTERNS

    parser = cli._build_parser()
    (tune_action,) = [
        a
        for sub in parser._subparsers._group_actions
        for a in sub.choices["tune"]._actions
        if a.dest == "target_pattern"
    ]
    assert set(tune_action.choices) == set(ALL_PATTERNS)
    # and a typo fails loudly instead of silently tuning nothing
    with pytest.raises(SystemExit) as exc:
        cli.main(["tune", "gemm", "--target-pattern", "hotrandom",
                  "--out", str(tmp_path / "s")])
    assert exc.value.code == 2


@pytest.mark.parametrize("spec", ["bogus", "window:abc", "window:", "window:0"])
def test_bad_sampler_fails(tmp_path, spec):
    with pytest.raises(SystemExit) as exc:
        cli.main(["profile", "--kernel", "gemm", "--out",
                  str(tmp_path / "s"), "--sampler", spec])
    assert exc.value.code == 2  # usage error, not regression (exit 1)


def test_two_variants_one_invocation_get_distinct_names(tmp_path):
    from repro.core.session import load_iteration

    sess = str(tmp_path / "sess")
    assert cli.main(["profile", "--kernel", "ttm", "--kernel", "ttm:fused",
                     "--out", sess, "--quiet"]) == 0
    it = load_iteration(os.path.join(sess, "iter0"))
    assert sorted(it.kernel_names()) == ["ttm:fused", "ttm:scratch"]
    # both stay addressable (no silent shadowing)
    assert it.kernel("ttm:fused").variant == "fused"


def test_repeated_refs_deduped(tmp_path):
    from repro.core.session import load_iteration

    sess = str(tmp_path / "sess")
    # 'ttm' and 'ttm:scratch' resolve identically; no crash, one kernel
    assert cli.main(["profile", "--kernel", "ttm", "--kernel", "ttm",
                     "--kernel", "ttm:scratch", "--out", sess,
                     "--quiet"]) == 0
    it = load_iteration(os.path.join(sess, "iter0"))
    assert it.kernel_names() == ["ttm"]


def test_kernels_lists_registry(capsys):
    assert cli.main(["kernels"]) == 0
    out = capsys.readouterr().out
    for name in ("gemm", "spmv", "histogram", "gramschm"):
        assert name in out
    assert "v00" in out  # variants shown
