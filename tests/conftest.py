import os

# Tests run on the single real CPU device; ONLY the dry-run subprocesses
# use placeholder devices (they set XLA_FLAGS themselves).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
