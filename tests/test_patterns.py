"""Detector precision on synthesized heat maps — one test per paper pattern."""

import numpy as np
import pytest

from repro.core import detect_all
from repro.core.heatmap import Analyzer
from repro.core.patterns import (
    FALSE_SHARING,
    HOT,
    HOT_RANDOM,
    MISALIGNMENT,
    SCRATCH_ABUSE,
    STRIDED,
)
from repro.core.tiles import TileGeometry
from repro.core.trace import AccessRecord, RegionInfo, TraceBuffer


def _heatmap(records, shape=(64, 256), space="hbm", n_programs=8):
    buf = TraceBuffer()
    geom = TileGeometry(shape=shape, itemsize=4, name="A")
    buf.register_region(RegionInfo("A", geom, space=space))
    for pid, touches in records:
        buf.append(
            AccessRecord(array="A", site="k/A", space=space, kind="load",
                         program_id=(pid,), touches=tuple(touches)))
    an = Analyzer("k", (n_programs,), "full")
    an.ingest(buf)
    return an.flush()


def _patterns(hm):
    return {r.pattern for r in detect_all(hm)}


def test_hot_detected():
    # every program touches every word of every sector (uniform hot)
    recs = [(p, [(t, w) for t in range(8) for w in range(8)]) for p in range(8)]
    assert HOT in _patterns(_heatmap(recs))


def test_hot_random_detected():
    rng = np.random.default_rng(1)
    recs = []
    for p in range(16):
        touches = []
        for t in range(8):
            # random subsets of words, multiple warm words/sector
            ws = rng.choice(8, size=rng.integers(2, 6), replace=False)
            if rng.random() < 0.7:
                touches += [(t, int(w)) for w in ws]
        recs.append((p, touches))
    pats = _patterns(_heatmap(recs, n_programs=16))
    assert HOT_RANDOM in pats or HOT in pats


def test_false_sharing_detected():
    # 8 programs each own one word of each sector
    recs = [(p, [(t, p) for t in range(8)]) for p in range(8)]
    pats = _patterns(_heatmap(recs))
    assert FALSE_SHARING in pats
    assert STRIDED not in pats


def test_strided_detected():
    # all programs hit word 0 of every sector; words 1-7 cold
    recs = [(p, [(t, 0) for t in range(16)]) for p in range(8)]
    pats = _patterns(_heatmap(recs, shape=(128, 256)))
    assert STRIDED in pats
    assert FALSE_SHARING not in pats


def test_misalignment_detected():
    # every program reads 8 words starting at word 4 of its tile: head-4
    # words of the NEXT tile get one extra contributor
    recs = []
    for p in range(8):
        touches = [(p, w) for w in range(4, 8)] + [(p + 1, w) for w in range(4)]
        recs.append((p, touches))
    pats = _patterns(_heatmap(recs, shape=(80, 128), n_programs=8))
    assert MISALIGNMENT in pats


def test_scratch_abuse_detected():
    # scratch where each word is touched by exactly one program
    recs = [(p, [(0, p)]) for p in range(8)]
    hm = _heatmap(recs, shape=(8, 128), space="vmem_scratch")
    reports = [r for r in detect_all(hm) if r.pattern == SCRATCH_ABUSE]
    assert reports and reports[0].severity >= 0.75


def test_scratch_shared_not_flagged():
    # scratch where everyone touches everything: proper shared use
    recs = [(p, [(0, w) for w in range(8)]) for p in range(8)]
    hm = _heatmap(recs, shape=(8, 128), space="vmem_scratch")
    assert SCRATCH_ABUSE not in _patterns(hm)


def test_coalesced_clean():
    # one program per sector touching all words: no pattern at all
    recs = [(p, [(p, w) for w in range(8)]) for p in range(8)]
    assert _patterns(_heatmap(recs)) == set()


def test_advisor_ranks_by_saving():
    from repro.core import advise

    recs = [(p, [(t, p) for t in range(8)]) for p in range(8)]
    hm = _heatmap(recs)
    actions = advise(hm)
    assert actions and actions[0].kind == "retile"
    assert actions[0].est_transaction_saving > 0.5
