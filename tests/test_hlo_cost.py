"""Trip-count-aware HLO cost model: validated against XLA on loop-free
modules and against analytic counts on scan loops."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hlo_cost


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def _xla_cost(co):
    ca = co.cost_analysis()
    return dict(ca[0] if isinstance(ca, (list, tuple)) else ca)


def test_loopfree_matches_xla():
    def g(a, b):
        return jnp.tanh(a @ b) @ b

    co = _compile(g, jax.ShapeDtypeStruct((256, 512), jnp.float32),
                  jax.ShapeDtypeStruct((512, 512), jnp.float32))
    want = _xla_cost(co)
    got = hlo_cost.analyze(co.as_text())
    assert abs(got.flops - want["flops"]) / want["flops"] < 0.01
    assert abs(got.bytes - want["bytes accessed"]) / want["bytes accessed"] < 0.05


def test_scan_multiplies_body_by_trip_count():
    def f(x, w):
        def body(c, _):
            return c @ w, None

        y, _ = jax.lax.scan(body, x, None, length=10)
        return y.sum()

    co = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                  jax.ShapeDtypeStruct((128, 128), jnp.float32))
    got = hlo_cost.analyze(co.as_text())
    expect = 2 * 128**3 * 10
    assert abs(got.flops - expect) / expect < 0.05
    # XLA's own analysis single-counts (documents why hlo_cost exists)
    assert _xla_cost(co)["flops"] < expect / 5


def test_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None

            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None

        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y.sum()

    co = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                  jax.ShapeDtypeStruct((64, 64), jnp.float32))
    got = hlo_cost.analyze(co.as_text())
    expect = 2 * 64**3 * 12
    assert abs(got.flops - expect) / expect < 0.1


def test_dynamic_slice_counts_slice_not_buffer():
    # scanning over a big stacked operand must not charge the full stack
    # per iteration
    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None

        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    co = _compile(f, jax.ShapeDtypeStruct((20, 128, 128), jnp.float32),
                  jax.ShapeDtypeStruct((8, 128), jnp.float32))
    got = hlo_cost.analyze(co.as_text())
    stack_bytes = 20 * 128 * 128 * 4
    # total bytes must be ~ O(stack read once), NOT 20x the stack
    assert got.bytes < 6 * stack_bytes


def test_parse_tuple_shaped_while():
    text = """
HloModule m, entry_computation_layout={()->f32[]}

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %c1 = s32[] constant(1)
  %a = s32[] add(%g0, %c1)
  %g1 = f32[4] get-tuple-element(%p), index=1
  %e = f32[4] exponential(%g1)
  ROOT %t = (s32[], f32[4]) tuple(%a, %e)
}

%cond (p2: (s32[], f32[4])) -> pred[] {
  %p2 = (s32[], f32[4]) parameter(0)
  %g = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%g, %n), direction=LT
}

ENTRY %main () -> f32[] {
  %z = s32[] constant(0)
  %x = f32[4] constant({1,2,3,4})
  %t0 = (s32[], f32[4]) tuple(%z, %x)
  %w = (s32[], f32[4]) while(%t0), condition=%cond, body=%body
  %o = f32[4] get-tuple-element(%w), index=1
  ROOT %r = f32[] reduce-something(%o)
}
"""
    model = hlo_cost.HloCostModel(text)
    assert "body" in model.comps and "main" in model.comps
    assert model.trip_count("cond") == 7
    cost = model.entry_cost()
    # exponential: 4 elements x 7 trips (+ reduce etc.)
    assert cost.flops >= 28


def test_collectives_in_loops_scaled():
    text = """
HloModule m

%body (p: (s32[], f32[1024])) -> (s32[], f32[1024]) {
  %p = (s32[], f32[1024]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %c1 = s32[] constant(1)
  %a = s32[] add(%g0, %c1)
  %g1 = f32[1024] get-tuple-element(%p), index=1
  %ag = f32[1024] all-reduce(%g1), replica_groups=[4,2]<=[8], to_apply=%sum
  ROOT %t = (s32[], f32[1024]) tuple(%a, %ag)
}

%cond (p2: (s32[], f32[1024])) -> pred[] {
  %p2 = (s32[], f32[1024]) parameter(0)
  %g = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%g, %n), direction=LT
}

ENTRY %main (x: f32[1024]) -> f32[1024] {
  %z = s32[] constant(0)
  %x = f32[1024] parameter(0)
  %t0 = (s32[], f32[1024]) tuple(%z, %x)
  %w = (s32[], f32[1024]) while(%t0), condition=%cond, body=%body
  ROOT %o = f32[1024] get-tuple-element(%w), index=1
}
"""
    cost = hlo_cost.analyze(text, total_devices=8)
    # all-reduce: 2*(g-1)/g*B with g=2, B=4096 bytes -> 4096/iter x 5 iters
    assert cost.wire_bytes == pytest.approx(5 * 4096, rel=0.01)
