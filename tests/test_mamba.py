"""Mamba2/SSD: chunked vs sequential oracle, decode parity, conv cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests degrade to skips
from hypothesis import given, settings, strategies as st

from repro.models.mamba import (
    SSMConfig,
    causal_conv,
    causal_conv_step,
    init_mamba_cache,
    mamba_apply,
    mamba_defs,
    ssd_naive_ref,
    ssd_ref,
)
from repro.models.params import init_params


@given(
    s=st.sampled_from([16, 32, 64]),
    chunk=st.sampled_from([8, 16, 32]),
    h=st.sampled_from([1, 3]),
)
@settings(max_examples=12, deadline=None)
def test_ssd_chunked_equals_sequential(s, chunk, h):
    if s % chunk:
        chunk = s
    b, p, n = 2, 8, 4
    x = jax.random.normal(jax.random.key(0), (b, s, h, p))
    a = -jnp.abs(jax.random.normal(jax.random.key(1), (b, s, h))) * 0.4
    bm = jax.random.normal(jax.random.key(2), (b, s, h, n))
    cm = jax.random.normal(jax.random.key(3), (b, s, h, n))
    y1, s1 = ssd_ref(x, a, bm, cm, chunk=chunk)
    y2, s2 = ssd_naive_ref(x, a, bm, cm)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s1, s2, atol=1e-4, rtol=1e-4)


def test_ssd_initial_state_threading():
    b, s, h, p, n = 1, 16, 2, 4, 4
    x = jax.random.normal(jax.random.key(0), (b, s, h, p))
    a = -jnp.abs(jax.random.normal(jax.random.key(1), (b, s, h))) * 0.3
    bm = jax.random.normal(jax.random.key(2), (b, s, h, n))
    cm = jax.random.normal(jax.random.key(3), (b, s, h, n))
    # full pass == two half passes threading the state
    y_full, s_full = ssd_ref(x, a, bm, cm, chunk=8)
    y1, s1 = ssd_ref(x[:, :8], a[:, :8], bm[:, :8], cm[:, :8], chunk=8)
    y2, s2 = ssd_ref(x[:, 8:], a[:, 8:], bm[:, 8:], cm[:, 8:], chunk=8,
                     initial_state=s1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s2, s_full, atol=1e-4, rtol=1e-4)


def test_causal_conv_step_matches_full():
    b, s, c, k = 2, 10, 6, 4
    x = jax.random.normal(jax.random.key(0), (b, s, c))
    w = jax.random.normal(jax.random.key(1), (k, c)) * 0.5
    bias = jax.random.normal(jax.random.key(2), (c,)) * 0.1
    full = causal_conv(x, w, bias)
    state = jnp.zeros((b, k - 1, c))
    outs = []
    for t in range(s):
        y, state = causal_conv_step(state, x[:, t], w, bias)
        outs.append(y)
    np.testing.assert_allclose(jnp.stack(outs, 1), full, atol=1e-5, rtol=1e-5)


def test_mamba_layer_decode_matches_full():
    cfg = SSMConfig(d_model=32, d_state=8, head_dim=16, expand=2, chunk=4)
    params = init_params(mamba_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, 32))
    full, _ = mamba_apply(params, x, cfg)
    cache = init_mamba_cache(2, cfg, jnp.float32)
    y, cache = mamba_apply(params, x[:, :4], cfg, cache)
    np.testing.assert_allclose(y, full[:, :4], atol=1e-4, rtol=1e-3)
    for t in range(4, 8):
        y, cache = mamba_apply(params, x[:, t : t + 1], cfg, cache)
        np.testing.assert_allclose(y[:, 0], full[:, t], atol=1e-4, rtol=1e-3)


def test_mamba_grads_finite():
    cfg = SSMConfig(d_model=16, d_state=4, head_dim=8, expand=2, chunk=4)
    params = init_params(mamba_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 8, 16))

    def loss(p):
        y, _ = mamba_apply(p, x, cfg)
        return jnp.sum(y**2)

    g = jax.tree.leaves(jax.grad(loss)(params))
    assert all(np.isfinite(np.asarray(v)).all() for v in g)
