"""Columnar TraceBuffer: record view, caps, and drop accounting."""

import numpy as np

from repro.core.heatmap import Analyzer
from repro.core.tiles import TileGeometry
from repro.core.trace import (
    AccessRecord,
    RegionInfo,
    SiteInfo,
    TraceBuffer,
    sampled_grid,
    sampled_grid_array,
    GridSampler,
)


def _site(name="A"):
    return SiteInfo(array=name, site=f"k/{name}", space="hbm", kind="load")


def _region(buf, name="A", shape=(64, 256)):
    buf.register_region(
        RegionInfo(name, TileGeometry(shape=shape, itemsize=4, name=name))
    )


def test_append_block_broadcast_record_view():
    buf = TraceBuffer()
    _region(buf)
    pids = np.arange(4)[:, None]
    buf.append_block(_site(), pids, np.array([0, 1]), np.array([2, 3]))
    assert len(buf) == 4
    recs = list(buf.records)
    assert [r.program_id for r in recs] == [(0,), (1,), (2,), (3,)]
    assert all(r.touches == ((0, 2), (1, 3)) for r in recs)


def test_append_block_csr_record_view():
    buf = TraceBuffer()
    _region(buf)
    buf.append_block(
        _site(),
        np.array([[0], [1]]),
        np.array([5, 6, 7]),
        np.array([0, 1, 2]),
        ptr=np.array([0, 1, 3]),
    )
    recs = list(buf.records)
    assert recs[0].touches == ((5, 0),)
    assert recs[1].touches == ((6, 1), (7, 2))


def test_mixed_append_orders_preserved():
    buf = TraceBuffer()
    _region(buf)
    buf.append(
        AccessRecord("A", "k/A", "hbm", "load", (9,), ((1, 1),))
    )
    buf.append_block(_site(), np.array([[0]]), np.array([2]), np.array([0]))
    recs = list(buf.records)
    assert [r.program_id for r in recs] == [(9,), (0,)]
    assert len(buf) == 2


def test_max_records_cap_truncates_block_and_counts_drops():
    buf = TraceBuffer(max_records=3)
    _region(buf)
    buf.append_block(
        _site(), np.arange(5)[:, None], np.array([0]), np.array([0])
    )
    assert len(buf) == 3 and buf.dropped == 2
    # CSR block entirely dropped once full
    buf.append_block(
        _site(),
        np.array([[7], [8]]),
        np.array([1, 2]),
        np.array([0, 0]),
        ptr=np.array([0, 1, 2]),
    )
    assert len(buf) == 3 and buf.dropped == 4
    recs = list(buf.records)
    assert [r.program_id for r in recs] == [(0,), (1,), (2,)]


def test_max_records_cap_csr_truncation_keeps_touch_alignment():
    buf = TraceBuffer(max_records=2)
    _region(buf)
    buf.append_block(
        _site(),
        np.array([[0], [1], [2]]),
        np.array([0, 1, 2, 3, 4, 5]),
        np.array([0, 1, 2, 3, 4, 5]),
        ptr=np.array([0, 2, 4, 6]),
    )
    assert len(buf) == 2 and buf.dropped == 1
    recs = list(buf.records)
    assert recs[0].touches == ((0, 0), (1, 1))
    assert recs[1].touches == ((2, 2), (3, 3))


def test_per_record_append_respects_cap():
    buf = TraceBuffer(max_records=2)
    _region(buf)
    for p in range(5):
        buf.append(
            AccessRecord("A", "k/A", "hbm", "load", (p,), ((0, 0),))
        )
    assert len(buf) == 2 and buf.dropped == 3


def test_dropped_surfaced_once_across_multiple_buffers():
    """Regression: drops from several ingested buffers sum exactly once."""
    bufs = []
    for lo in (0, 4):
        buf = TraceBuffer(max_records=2)
        _region(buf)
        buf.append_block(
            _site(), np.arange(lo, lo + 4)[:, None],
            np.array([0]), np.array([0]),
        )
        bufs.append(buf)
    an = Analyzer("k", (8,), "full")
    for buf in bufs:
        an.ingest(buf)
    hm = an.flush()
    assert hm.dropped == 4  # 2 per buffer, counted exactly once each
    assert hm.n_records == 4


def test_dropped_not_double_counted_on_reingest():
    """Regression: re-ingesting the same buffer must not re-surface its
    drops (or its records) — ingestion is an incremental drain."""
    buf = TraceBuffer(max_records=2)
    _region(buf)
    buf.append_block(
        _site(), np.arange(4)[:, None], np.array([0]), np.array([0])
    )
    an = Analyzer("k", (8,), "full")
    an.ingest(buf)
    an.ingest(buf)  # seed double-counted both records and drops here
    hm = an.flush()
    assert hm.dropped == 2
    assert hm.n_records == 2
    assert hm.regions[0].max_sector_temp == 2

    # incremental drain: later appends (and later drops) land on re-ingest
    buf2 = TraceBuffer(max_records=3)
    _region(buf2)
    buf2.append_block(_site(), np.array([[0]]), np.array([0]), np.array([0]))
    an2 = Analyzer("k", (8,), "full")
    an2.ingest(buf2)
    buf2.append_block(
        _site(), np.arange(1, 5)[:, None], np.array([0]), np.array([0])
    )
    an2.ingest(buf2)
    hm2 = an2.flush()
    assert hm2.n_records == 3 and hm2.dropped == 2
    assert hm2.regions[0].sector_temps_array.tolist() == [3]


def test_reingest_after_clear_treats_buffer_as_fresh():
    """Regression: clear()ing and refilling a buffer between ingests must
    ingest the new contents (and their drops) instead of silently skipping
    them behind the stale per-buffer cursor."""
    buf = TraceBuffer(max_records=1)
    _region(buf)
    buf.append_block(
        _site(), np.arange(2)[:, None], np.array([0]), np.array([0])
    )
    an = Analyzer("k", (8,), "full")
    an.ingest(buf)
    buf.clear()
    buf.append_block(
        _site(), np.arange(2, 5)[:, None], np.array([1]), np.array([0])
    )
    an.ingest(buf)
    hm = an.flush()
    assert hm.n_records == 2  # one admitted per fill
    assert hm.dropped == 3  # 1 from the first fill + 2 from the second
    assert hm.regions[0].tags_array.tolist() == [0, 1]


def test_clear_resets_columnar_state():
    buf = TraceBuffer(max_records=2)
    _region(buf)
    buf.append_block(
        _site(), np.arange(4)[:, None], np.array([0]), np.array([0])
    )
    buf.clear()
    assert len(buf) == 0 and buf.dropped == 0 and list(buf.records) == []


def test_sampled_grid_array_matches_generator():
    cases = [
        ((16,), GridSampler((0,), window=4)),
        ((16,), GridSampler((1,), window=4)),
        ((4, 2), GridSampler((0,), window=2)),
        ((2, 3, 4), GridSampler((1, 2))),
        ((2, 3, 4), GridSampler(None)),
        ((5,), GridSampler(())),
        ((), GridSampler((0,))),
    ]
    for grid, sampler in cases:
        want = list(sampled_grid(grid, sampler))
        got = [tuple(int(x) for x in row)
               for row in sampled_grid_array(grid, sampler)]
        assert got == want, (grid, sampler.describe())
