"""Heat-map diffing (the paper's iterate loop)."""

import numpy as np

from repro.core import analyze
from repro.core.diff import diff
from repro.core.trace import GridSampler
from repro.kernels.gemm import gemm_v00_spec, gemm_v01_spec
from repro.kernels.gramschm import k3_naive_spec, k3_opt_spec


def test_diff_gemm_shows_fix_and_speedup():
    S = GridSampler((0,), window=32)
    before = analyze(gemm_v00_spec(1024, 1024, 1024), S)
    after = analyze(gemm_v01_spec(1024, 1024, 1024), S)
    d = diff(before, after)
    assert ("C", "false-sharing") in d.fixed
    assert not any(p == "false-sharing" for _, p in d.introduced)
    assert d.tx_before > 0 and d.tx_after > 0
    assert "thermo diff" in d.summary()


def test_diff_with_region_rename():
    before = analyze(k3_naive_spec(512, 512, 512, k=3), GridSampler(None))
    after = analyze(k3_opt_spec(512, 512, 512, k=3), GridSampler(None))
    d = diff(before, after, region_map={"q": "qT"})
    assert ("q", "strided") in d.fixed
    assert d.speedup_estimate > 1.5


def test_diff_identical_is_clean():
    S = GridSampler((0,), window=32)
    hm = analyze(gemm_v00_spec(256, 256, 256), S)
    hm2 = analyze(gemm_v00_spec(256, 256, 256), S)
    d = diff(hm, hm2)
    assert d.fixed == () and d.introduced == ()
    assert abs(d.speedup_estimate - 1.0) < 1e-9


def test_verdict_property():
    from repro.core.diff import HeatmapDiff

    def hd(tx_before, tx_after, fixed=(), introduced=()):
        return HeatmapDiff(
            kernel_before="a", kernel_after="b", regions=(),
            fixed=tuple(fixed), introduced=tuple(introduced),
            persisting=(), tx_before=tx_before, tx_after=tx_after,
        )

    assert hd(100, 50).verdict == "improved"
    assert hd(100, 200).verdict == "regressed"
    assert hd(100, 100).verdict == "unchanged"
    # a new pattern without reduced traffic is a regression, even when
    # another pattern was fixed in trade
    assert hd(100, 100, introduced=[("r", "p2")]).verdict == "regressed"
    assert hd(
        100, 100, fixed=[("r", "p1")], introduced=[("r", "p2")]
    ).verdict == "regressed"
    # reduced traffic wins even with a new (milder) pattern
    assert hd(100, 50, introduced=[("r", "p2")]).verdict == "improved"
