"""Heat-map diffing (the paper's iterate loop)."""

import numpy as np

from repro.core import analyze
from repro.core.diff import diff
from repro.core.trace import GridSampler
from repro.kernels.gemm import gemm_v00_spec, gemm_v01_spec
from repro.kernels.gramschm import k3_naive_spec, k3_opt_spec


def test_diff_gemm_shows_fix_and_speedup():
    S = GridSampler((0,), window=32)
    before = analyze(gemm_v00_spec(1024, 1024, 1024), S)
    after = analyze(gemm_v01_spec(1024, 1024, 1024), S)
    d = diff(before, after)
    assert ("C", "false-sharing") in d.fixed
    assert not any(p == "false-sharing" for _, p in d.introduced)
    assert d.tx_before > 0 and d.tx_after > 0
    assert "thermo diff" in d.summary()


def test_diff_with_region_rename():
    before = analyze(k3_naive_spec(512, 512, 512, k=3), GridSampler(None))
    after = analyze(k3_opt_spec(512, 512, 512, k=3), GridSampler(None))
    d = diff(before, after, region_map={"q": "qT"})
    assert ("q", "strided") in d.fixed
    assert d.speedup_estimate > 1.5


def test_diff_identical_is_clean():
    S = GridSampler((0,), window=32)
    hm = analyze(gemm_v00_spec(256, 256, 256), S)
    hm2 = analyze(gemm_v00_spec(256, 256, 256), S)
    d = diff(hm, hm2)
    assert d.fixed == () and d.introduced == ()
    assert abs(d.speedup_estimate - 1.0) < 1e-9


def test_verdict_property():
    from repro.core.diff import HeatmapDiff

    def hd(tx_before, tx_after, fixed=(), introduced=()):
        return HeatmapDiff(
            kernel_before="a", kernel_after="b", regions=(),
            fixed=tuple(fixed), introduced=tuple(introduced),
            persisting=(), tx_before=tx_before, tx_after=tx_after,
        )

    assert hd(100, 50).verdict == "improved"
    assert hd(100, 200).verdict == "regressed"
    assert hd(100, 100).verdict == "unchanged"
    # a new pattern without reduced traffic is a regression, even when
    # another pattern was fixed in trade
    assert hd(100, 100, introduced=[("r", "p2")]).verdict == "regressed"
    assert hd(
        100, 100, fixed=[("r", "p1")], introduced=[("r", "p2")]
    ).verdict == "regressed"
    # reduced traffic wins even with a new (milder) pattern
    assert hd(100, 50, introduced=[("r", "p2")]).verdict == "improved"


# -- property-based: the verdict algebra over arbitrary heat maps -----------
#
# Hand-built heat maps (tiny synthetic regions, arbitrary temperatures)
# drive `diff` through its full alignment/rename/verdict path.  When
# hypothesis is unavailable the deterministic tests above still pin the
# core cases.

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # property tests degrade to the deterministic ones
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from repro.core.heatmap import Heatmap, RegionHeatmap
    from repro.core.tiles import TileGeometry
    from repro.core.trace import RegionInfo

    _REGION_NAMES = ("A", "B", "C")

    @st.composite
    def _region(draw, name):
        """One synthetic region heat map with arbitrary temperatures."""
        geometry = TileGeometry((16, 128), itemsize=4, name=name)
        wps = 8  # float32: 8 sublane rows per native tile
        n_sectors = draw(st.integers(min_value=1, max_value=4))
        tags = np.arange(n_sectors, dtype=np.int64) * wps
        word_temps = np.asarray(
            draw(
                st.lists(
                    st.lists(
                        st.integers(min_value=0, max_value=4),
                        min_size=wps, max_size=wps,
                    ),
                    min_size=n_sectors, max_size=n_sectors,
                )
            ),
            dtype=np.int64,
        )
        # a sector is at least as hot as its hottest word
        extra = draw(st.integers(min_value=0, max_value=3))
        sector_temps = np.maximum(word_temps.max(axis=1), 1) + extra
        return RegionHeatmap(
            RegionInfo(name=name, geometry=geometry, space="hbm"),
            n_programs=draw(st.integers(min_value=1, max_value=64)),
            tags=tags,
            word_temps=word_temps,
            sector_temps=sector_temps.astype(np.int64),
        )

    @st.composite
    def _heatmap(draw, kernel="k"):
        n_regions = draw(st.integers(min_value=1, max_value=3))
        regions = tuple(
            draw(_region(_REGION_NAMES[i])) for i in range(n_regions)
        )
        return Heatmap(
            kernel=kernel,
            grid=(4,),
            sampler="full",
            regions=regions,
            n_records=64,
            dropped=0,
        )

    @given(hm=_heatmap())
    @settings(max_examples=30, deadline=None)
    def test_property_self_diff_never_regresses(hm):
        """PROPERTY: diff(a, a) is 'unchanged' — never a regression."""
        d = diff(hm, hm)
        assert d.verdict == "unchanged"
        assert d.fixed == () and d.introduced == ()
        assert d.tx_before == d.tx_after

    @given(a=_heatmap("a"), b=_heatmap("b"))
    @settings(max_examples=30, deadline=None)
    def test_property_swap_exchanges_improved_and_regressed(a, b):
        """PROPERTY: swapping before/after exchanges the verdicts.

        'improved' always flips to 'regressed'.  The reverse is
        one-directional: a regression caused purely by an introduced
        pattern at equal traffic swaps to 'unchanged' (losing a pattern
        is not an improvement), so only traffic-driven regressions flip
        all the way back to 'improved'.
        """
        fwd, rev = diff(a, b), diff(b, a)
        # the pattern bookkeeping is exactly mirrored
        assert set(fwd.fixed) == set(rev.introduced)
        assert set(fwd.introduced) == set(rev.fixed)
        assert set(fwd.persisting) == set(rev.persisting)
        if fwd.verdict == "improved":
            assert rev.verdict == "regressed"
        if fwd.verdict == "regressed" and fwd.tx_after > fwd.tx_before:
            assert rev.verdict == "improved"
        if fwd.verdict == "unchanged":
            assert rev.verdict in ("unchanged", "regressed")

    @given(a=_heatmap("a"), b=_heatmap("b"), data=st.data())
    @settings(max_examples=30, deadline=None)
    def test_property_verdict_invariant_under_region_rename(a, b, data):
        """PROPERTY: renaming an after-region (with the matching
        --region-map entry) never changes the verdict or the traffic."""
        baseline = diff(a, b)
        # rename one of b's regions to something fresh
        victim = data.draw(
            st.sampled_from([rh.region.name for rh in b.regions])
        )
        new_name = victim + "_renamed"
        renamed_regions = tuple(
            RegionHeatmap(
                RegionInfo(
                    name=new_name if rh.region.name == victim
                    else rh.region.name,
                    geometry=rh.region.geometry,
                    space=rh.region.space,
                ),
                n_programs=rh.n_programs,
                tags=rh.tags_array,
                word_temps=rh.word_temps_matrix,
                sector_temps=rh.sector_temps_array,
            )
            for rh in b.regions
        )
        b2 = Heatmap(
            kernel=b.kernel, grid=b.grid, sampler=b.sampler,
            regions=renamed_regions, n_records=b.n_records,
            dropped=b.dropped,
        )
        d2 = diff(a, b2, region_map={victim: new_name})
        assert d2.verdict == baseline.verdict
        assert d2.tx_before == baseline.tx_before
        assert d2.tx_after == baseline.tx_after
        assert set(d2.fixed) == set(baseline.fixed)
        assert set(d2.introduced) == set(baseline.introduced)
