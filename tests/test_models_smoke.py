"""Per-arch SMOKE tests: reduced same-family config, one forward + one
train step on CPU, asserting output shapes + no NaNs (the assignment's
required smoke matrix)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SUBQUADRATIC, get_config
from repro.models import build_model
from repro.optim import adamw, constant
from repro.runtime import TrainConfig, build_train_step, init_state


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_train_step(arch_id):
    cfg = get_config(arch_id, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)
    labels = jnp.roll(tokens, -1, axis=1)

    # forward
    if cfg.family == "audio":
        frames = jnp.zeros((b, 8, cfg.d_model), cfg.dtype)
        logits, _, aux = model.apply(params, tokens, embeddings=frames)
    else:
        logits, _, aux = model.apply(params, tokens)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())

    # one real train step
    opt = adamw(constant(1e-3))

    def loss_fn(p, t, l):
        if cfg.family == "audio":
            fr = jnp.zeros((t.shape[0], 8, cfg.d_model), cfg.dtype)
            return model.loss(p, t, l, frames=fr)
        return model.loss(p, t, l)

    tc = TrainConfig()
    state = init_state(params, opt, tc)
    step = build_train_step(loss_fn, opt, tc, donate=False)
    state2, metrics = step(state, tokens, labels)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = max(
        float(jnp.abs(a - b_).max())
        for a, b_ in zip(jax.tree.leaves(state.params), jax.tree.leaves(state2.params))
    )
    assert delta > 0


@pytest.mark.parametrize("arch_id", ["granite-8b", "mamba2-2.7b", "jamba-v0.1-52b",
                                     "deepseek-v3-671b", "whisper-base"])
def test_smoke_decode(arch_id):
    """Prefill + one decode step on the reduced config."""
    cfg = get_config(arch_id, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b = 2
    tokens = jax.random.randint(jax.random.key(1), (b, 8), 0, cfg.vocab)
    caches = model.init_caches(b, 32, dtype=jnp.float32)
    if cfg.family == "audio":
        frames = jnp.zeros((b, 8, cfg.d_model), cfg.dtype)
        logits, caches, _ = model.apply(params, tokens, caches=caches,
                                        embeddings=frames)
        logits, caches = model.decode_step(params, tokens[:, :1], caches,
                                           embeddings=frames)
    else:
        logits, caches = model.prefill(params, tokens, caches)
        logits, caches = model.decode_step(params, tokens[:, :1], caches)
    assert logits.shape[0] == b and logits.shape[1] == 1
    assert not bool(jnp.isnan(logits).any())


def test_layouts_match_assignment():
    """Layout structure sanity for the structured archs."""
    ds = get_config("deepseek-v3-671b")
    lo = ds.layout()
    assert len(lo) == 61
    assert all(k.mixer == "mla" for k in lo)
    assert [k.ffn for k in lo[:3]] == ["mlp"] * 3 and lo[3].ffn == "moe"

    jb = get_config("jamba-v0.1-52b")
    lo = jb.layout()
    assert len(lo) == 32
    assert sum(1 for k in lo if k.mixer == "attn") == 4  # 1:7 ratio
    assert sum(1 for k in lo if k.ffn == "moe") == 16  # every other layer
    assert lo[4].mixer == "attn"

    mb = get_config("mamba2-2.7b")
    assert all(k.mixer == "mamba" and k.ffn == "none" for k in mb.layout())


def test_param_counts_match_public_sizes():
    expect = {
        "granite-20b": (20.1e9, 0.06),
        "deepseek-v3-671b": (670.8e9, 0.02),
        "jamba-v0.1-52b": (51.2e9, 0.05),
        "mamba2-2.7b": (2.7e9, 0.1),
        "qwen2-vl-72b": (71.5e9, 0.05),
    }
    for arch, (want, tol) in expect.items():
        total, _ = get_config(arch).param_counts()
        assert abs(total - want) / want < tol, (arch, total)


def test_active_params_moe():
    total, active = get_config("deepseek-v3-671b").param_counts()
    assert 35e9 < active < 40e9  # paper: 37B activated
    total, active = get_config("llama4-scout-17b-a16e").param_counts()
    assert 14e9 < active < 19e9  # ~17B activated
