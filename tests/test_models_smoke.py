"""Per-arch SMOKE tests: reduced same-family config, one forward + one
train step on CPU, asserting output shapes + no NaNs (the assignment's
required smoke matrix) — plus the registered ``cuthermo model`` configs
(transformer-tiny / moe-tiny / mamba-tiny): forward shape+dtype, grad
finiteness through the loss, and bit-exact determinism under a fixed
seed (the property whole-model profiling and its CI job lean on)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SUBQUADRATIC, get_config
from repro.models import build_model
from repro.models.registry import MODELS, get_model, model_names
from repro.optim import adamw, constant
from repro.runtime import TrainConfig, build_train_step, init_state


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_train_step(arch_id):
    cfg = get_config(arch_id, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)
    labels = jnp.roll(tokens, -1, axis=1)

    # forward
    if cfg.family == "audio":
        frames = jnp.zeros((b, 8, cfg.d_model), cfg.dtype)
        logits, _, aux = model.apply(params, tokens, embeddings=frames)
    else:
        logits, _, aux = model.apply(params, tokens)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())

    # one real train step
    opt = adamw(constant(1e-3))

    def loss_fn(p, t, l):
        if cfg.family == "audio":
            fr = jnp.zeros((t.shape[0], 8, cfg.d_model), cfg.dtype)
            return model.loss(p, t, l, frames=fr)
        return model.loss(p, t, l)

    tc = TrainConfig()
    state = init_state(params, opt, tc)
    step = build_train_step(loss_fn, opt, tc, donate=False)
    state2, metrics = step(state, tokens, labels)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = max(
        float(jnp.abs(a - b_).max())
        for a, b_ in zip(jax.tree.leaves(state.params), jax.tree.leaves(state2.params))
    )
    assert delta > 0


@pytest.mark.parametrize("arch_id", ["granite-8b", "mamba2-2.7b", "jamba-v0.1-52b",
                                     "deepseek-v3-671b", "whisper-base"])
def test_smoke_decode(arch_id):
    """Prefill + one decode step on the reduced config."""
    cfg = get_config(arch_id, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b = 2
    tokens = jax.random.randint(jax.random.key(1), (b, 8), 0, cfg.vocab)
    caches = model.init_caches(b, 32, dtype=jnp.float32)
    if cfg.family == "audio":
        frames = jnp.zeros((b, 8, cfg.d_model), cfg.dtype)
        logits, caches, _ = model.apply(params, tokens, caches=caches,
                                        embeddings=frames)
        logits, caches = model.decode_step(params, tokens[:, :1], caches,
                                           embeddings=frames)
    else:
        logits, caches = model.prefill(params, tokens, caches)
        logits, caches = model.decode_step(params, tokens[:, :1], caches)
    assert logits.shape[0] == b and logits.shape[1] == 1
    assert not bool(jnp.isnan(logits).any())


def test_layouts_match_assignment():
    """Layout structure sanity for the structured archs."""
    ds = get_config("deepseek-v3-671b")
    lo = ds.layout()
    assert len(lo) == 61
    assert all(k.mixer == "mla" for k in lo)
    assert [k.ffn for k in lo[:3]] == ["mlp"] * 3 and lo[3].ffn == "moe"

    jb = get_config("jamba-v0.1-52b")
    lo = jb.layout()
    assert len(lo) == 32
    assert sum(1 for k in lo if k.mixer == "attn") == 4  # 1:7 ratio
    assert sum(1 for k in lo if k.ffn == "moe") == 16  # every other layer
    assert lo[4].mixer == "attn"

    mb = get_config("mamba2-2.7b")
    assert all(k.mixer == "mamba" and k.ffn == "none" for k in mb.layout())


def test_param_counts_match_public_sizes():
    expect = {
        "granite-20b": (20.1e9, 0.06),
        "deepseek-v3-671b": (670.8e9, 0.02),
        "jamba-v0.1-52b": (51.2e9, 0.05),
        "mamba2-2.7b": (2.7e9, 0.1),
        "qwen2-vl-72b": (71.5e9, 0.05),
    }
    for arch, (want, tol) in expect.items():
        total, _ = get_config(arch).param_counts()
        assert abs(total - want) / want < tol, (arch, total)


def test_active_params_moe():
    total, active = get_config("deepseek-v3-671b").param_counts()
    assert 35e9 < active < 40e9  # paper: 37B activated
    total, active = get_config("llama4-scout-17b-a16e").param_counts()
    assert 14e9 < active < 19e9  # ~17B activated


# ---------------------------------------------------------------------------
# the registered `cuthermo model` configs
# ---------------------------------------------------------------------------


def _model_batch(name):
    entry = get_model(name)
    model = build_model(entry.config)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(
        jax.random.key(1), (entry.batch, entry.seq), 0, entry.config.vocab
    )
    return entry, model, params, tokens


@pytest.mark.parametrize("name", model_names())
def test_registered_model_forward_shape_and_dtype(name):
    entry, model, params, tokens = _model_batch(name)
    cfg = entry.config
    logits, _, _ = model.apply(params, tokens)
    assert logits.shape == (entry.batch, entry.seq, cfg.padded_vocab)
    assert logits.dtype == cfg.dtype
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", model_names())
def test_registered_model_grads_are_finite(name):
    entry, model, params, tokens = _model_batch(name)
    labels = jnp.roll(tokens, -1, axis=1)

    def scalar_loss(p):
        loss, _aux = model.loss(p, tokens, labels)
        return loss

    loss, grads = jax.value_and_grad(scalar_loss)(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves, "loss produced an empty grad tree"
    for g in leaves:
        assert bool(jnp.isfinite(g).all())
    # the loss actually depends on the parameters
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves)


@pytest.mark.parametrize("name", model_names())
def test_registered_model_forward_is_deterministic(name):
    # same seed, fresh params and fresh apply: bit-identical logits —
    # the invariant the `model-smoke` CI job's cached rerun relies on
    _, _, params_a, tokens_a = _model_batch(name)
    _, model, params_b, tokens_b = _model_batch(name)
    assert np.array_equal(np.asarray(tokens_a), np.asarray(tokens_b))
    for a, b in zip(jax.tree.leaves(params_a), jax.tree.leaves(params_b)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    la, _, _ = model.apply(params_a, tokens_a)
    lb, _, _ = model.apply(params_b, tokens_b)
    assert np.array_equal(np.asarray(la), np.asarray(lb))


def test_registered_model_shapes_are_ci_sized():
    # the registry promises CI-scale models; a config growth that would
    # blow up the model-smoke job budget should fail here first
    for name, entry in MODELS.items():
        cfg = entry.config
        assert cfg.n_layers <= 4, name
        assert cfg.d_model <= 256, name
        assert entry.batch * entry.seq <= 512, name
