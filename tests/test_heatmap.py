"""Analyzer invariants (the paper's sector_history_map), property-tested."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests degrade to skips
from hypothesis import given, settings, strategies as st

from repro.core.heatmap import Analyzer, SectorHistory, compress_rows
from repro.core.tiles import TileGeometry
from repro.core.trace import AccessRecord, RegionInfo, TraceBuffer


def _mk_buffer(records, shape=(64, 256), itemsize=4):
    buf = TraceBuffer()
    geom = TileGeometry(shape=shape, itemsize=itemsize, name="A")
    buf.register_region(RegionInfo("A", geom))
    for pid, touches in records:
        buf.append(
            AccessRecord(
                array="A", site="k/A", space="hbm", kind="load",
                program_id=pid, touches=tuple(touches),
            )
        )
    return buf


@given(
    data=st.lists(
        st.tuples(
            st.integers(0, 15),  # program id
            st.lists(
                st.tuples(st.integers(0, 15), st.integers(0, 7)),
                min_size=1, max_size=8,
            ),
        ),
        min_size=1, max_size=30,
    )
)
@settings(max_examples=50, deadline=None)
def test_sector_mask_is_or_of_word_masks(data):
    buf = _mk_buffer([((pid,), touches) for pid, touches in data])
    an = Analyzer("k", grid=(16,), sampler_desc="full")
    an.ingest(buf)
    # invariant on the raw bitmask state
    for smap in an._maps.values():
        for hist in smap.values():
            acc = 0
            for m in hist.word_masks:
                acc |= m
            assert acc == hist.sector_mask
    hm = an.flush()
    for rh in hm.regions:
        for row in rh.rows:
            assert row.sector_temp >= max(row.word_temps)
            assert row.sector_temp <= rh.n_programs
            # union bound: sector temp <= sum of word temps
            assert row.sector_temp <= max(1, sum(row.word_temps))


def test_paper_fig3_arithmetic():
    """Fig. 3: coalesced = 1 contributor/sector; false sharing = 8."""
    # (a) one program touches all 8 words of sector 0
    buf = _mk_buffer([((0,), [(0, w) for w in range(8)])])
    an = Analyzer("k", (8,), "full")
    an.ingest(buf)
    row = an.flush().regions[0].rows[0]
    assert row.sector_temp == 1 and set(row.word_temps) == {1}
    # (b) eight programs each touch a different word of sector 0
    buf = _mk_buffer([((p,), [(0, p)]) for p in range(8)])
    an = Analyzer("k", (8,), "full")
    an.ingest(buf)
    row = an.flush().regions[0].rows[0]
    assert row.sector_temp == 8 and set(row.word_temps) == {1}


def test_transaction_model_matches_paper():
    """Coalesced: 1 tile transfer; false-shared: 8 transfers."""
    coalesced = _mk_buffer([((0,), [(0, w) for w in range(8)])])
    shared = _mk_buffer([((p,), [(0, p)]) for p in range(8)])
    for buf, expect in ((coalesced, 1), (shared, 8)):
        an = Analyzer("k", (8,), "full")
        an.ingest(buf)
        assert an.flush().sector_transactions("A") == expect


def test_row_compression_lossless():
    rows = []
    buf = _mk_buffer(
        [((0,), [(t, w) for w in range(8)]) for t in range(10)]
        + [((1,), [(10, 0)])]
    )
    an = Analyzer("k", (16,), "full")
    an.ingest(buf)
    hm = an.flush()
    for rh in hm.regions:
        comp = compress_rows(rh.rows)
        assert sum(n for _, n in comp) == len(rh.rows)
        # identical consecutive signatures must collapse
        assert len(comp) == 2  # tags 0..9 identical, tag 10 distinct


def test_waste_ratio():
    # strided: 1 of 8 words used per sector -> waste 8x
    buf = _mk_buffer([((p,), [(t, 0) for t in range(8)]) for p in range(4)])
    an = Analyzer("k", (4,), "full")
    an.ingest(buf)
    hm = an.flush()
    assert abs(hm.waste_ratio("A") - 8.0) < 1e-9


def test_valid_words_edge_tiles():
    # array of 4 rows (half a tile): edge sectors have 4 valid words
    buf = _mk_buffer([((0,), [(0, 0)])], shape=(4, 128))
    an = Analyzer("k", (1,), "full")
    an.ingest(buf)
    rh = an.flush().regions[0]
    assert rh.valid_words(0) == 4
