"""Level-1/2 collection: block sampling, caching, origins, once-stores."""

import numpy as np

from repro.core import analyze, collect
from repro.core.collector import KernelSpec, OperandSpec, ScratchSpec, drain_dynamic
from repro.core.trace import GridSampler, KernelWhitelist, sampled_grid


def _toy_spec(m=64, n=64, k=64):
    return KernelSpec(
        name="toy",
        grid=(m // 8, n // 64),
        operands=(
            OperandSpec("A", (m, k), np.float32, (8, k), lambda i, j: (i, 0)),
            OperandSpec("B", (k, n), np.float32, (k, 64), lambda i, j: (0, j)),
            OperandSpec("C", (m, n), np.float32, (8, 64), lambda i, j: (i, j),
                        kind="store"),
        ),
    )


def test_block_sampling_reduces_records():
    spec = _toy_spec()
    full, stats_full = collect(spec, GridSampler(None))
    sampled, stats_s = collect(spec, GridSampler((0,)))
    assert stats_s.programs < stats_full.programs
    assert len(sampled) < len(full)
    # sampled admits exactly the grid row 0
    assert stats_s.programs == spec.grid[1]


def test_sampler_window():
    s = GridSampler((0,))
    assert s.admits((0, 5)) and not s.admits((1, 0))
    assert list(sampled_grid((2, 3), s)) == [(0, 0), (0, 1), (0, 2)]
    full = GridSampler(None)
    assert len(list(sampled_grid((2, 3), full))) == 6


def test_kernel_whitelist():
    wl = KernelWhitelist(["a", "b"])
    assert wl.admits("a") and not wl.admits("c")
    assert KernelWhitelist(None).admits("anything")


def test_origin_models_misalignment():
    aligned = KernelSpec(
        name="k", grid=(4,),
        operands=(OperandSpec("off", (4097,), np.int32, (1024,), lambda i: (i,)),),
    )
    shifted = KernelSpec(
        name="k", grid=(4,),
        operands=(
            OperandSpec("off", (4097,), np.int32, (1024,), lambda i: (i,),
                        origin=(0, 1)),
        ),
    )
    hm_a = analyze(aligned, GridSampler(None))
    hm_s = analyze(shifted, GridSampler(None))
    # the shifted view costs extra transfers (paper's 5-vs-4 economics)
    assert hm_s.sector_transactions() > hm_a.sector_transactions()


def test_once_store_counted_once():
    spec = KernelSpec(
        name="k", grid=(8,),
        operands=(
            OperandSpec("x", (8192,), np.int32, (1024,), lambda i: (i,)),
            OperandSpec("out", (1024,), np.float32, (1024,), lambda i: (0,),
                        kind="store", once=True),
        ),
    )
    hm = analyze(spec, GridSampler(None))
    out = hm.region("out")
    assert out.max_sector_temp == 1  # one program only


def test_drain_dynamic_level2():
    op = OperandSpec("x", (4096,), np.float32, (4096,), lambda i: (0,))
    # 4 programs, each touching flat indices around its own area
    trace = np.stack([np.arange(i * 128, i * 128 + 64) for i in range(4)])
    buf = drain_dynamic("k", (4,), op, trace, GridSampler(None))
    assert len(buf) == 4
    touched = {t for r in buf.records for t in r.touches}
    assert touched  # nonempty and valid tags
    for tag, w in touched:
        assert 0 <= w < 8


def test_scratch_regions_not_in_hbm_transactions():
    spec = KernelSpec(
        name="k", grid=(4,),
        operands=(OperandSpec("x", (4096,), np.float32, (1024,), lambda i: (i,)),),
        scratch=(ScratchSpec("s", (8, 128), np.float32),),
    )
    hm = analyze(spec, GridSampler(None))
    tx_all = hm.sector_transactions()
    tx_x = hm.sector_transactions("x")
    assert tx_all == tx_x  # scratch excluded from HBM transactions


# -- batch index-map evaluation: vectorized calls are validated, not trusted --


def test_batch_eval_catches_endpoint_agreeing_piecewise_map():
    """Adversarial regression: a vectorized map that matches the scalar
    evaluation at the batch's first and last program but lies in the
    middle.  Endpoint-only validation (the old check) accepted the
    vectorized result and miscollected every interior program; the
    middle sample must force the scalar fallback."""
    from repro.core.collector import _eval_index_map_batch

    n = 8

    def sneaky(i):
        if isinstance(i, np.ndarray):
            return (np.where((i == 0) | (i == n - 1), i, 0),)
        return (int(i),)

    pids = np.arange(n, dtype=np.int64).reshape(n, 1)
    got = _eval_index_map_batch(sneaky, pids)
    want = np.arange(n, dtype=np.int64).reshape(n, 1)
    assert np.array_equal(got, want)


def test_batch_eval_catches_arity_change():
    """A vectorized call returning a different arity than the scalar
    path must not be trusted either."""
    from repro.core.collector import _eval_index_map_batch

    def shapeshifter(i):
        if isinstance(i, np.ndarray):
            return (i, np.zeros_like(i))  # extra bogus component
        return (int(i),)

    pids = np.arange(6, dtype=np.int64).reshape(6, 1)
    got = _eval_index_map_batch(shapeshifter, pids)
    assert got.shape == (6, 1)
    assert np.array_equal(got[:, 0], np.arange(6))


def test_batch_eval_property_matches_scalar_rows():
    """Property: for any index map — affine, piecewise, broadcasting or
    not — the batch evaluation equals per-program scalar evaluation."""
    hypothesis = __import__("pytest").importorskip("hypothesis")
    st = __import__("pytest").importorskip("hypothesis.strategies")
    given, settings = hypothesis.given, hypothesis.settings

    @st.composite
    def _maps(draw):
        a = draw(st.integers(min_value=-3, max_value=3))
        b = draw(st.integers(min_value=0, max_value=7))
        pivot = draw(st.integers(min_value=0, max_value=12))
        kind = draw(st.sampled_from(["affine", "piecewise", "modular"]))
        if kind == "affine":
            return lambda i: (a * i + b,)
        if kind == "modular":
            return lambda i: (i % (pivot + 1), b)
        # piecewise: numpy-vectorizable via np.where, consistent with
        # the scalar branch for every i
        def pw(i):
            if isinstance(i, np.ndarray):
                return (np.where(i < pivot, i, a * i + b),)
            return (i if i < pivot else a * i + b,)
        return pw

    @settings(max_examples=60, deadline=None)
    @given(index_map=_maps(), p=st.integers(min_value=1, max_value=17))
    def _property(index_map, p):
        from repro.core.collector import _eval_index_map_batch

        pids = np.arange(p, dtype=np.int64).reshape(p, 1)
        got = _eval_index_map_batch(index_map, pids)
        want = np.asarray(
            [[int(x) for x in np.atleast_1d(index_map(int(i)))]
             for i in range(p)],
            dtype=np.int64,
        ).reshape(p, -1)
        assert np.array_equal(got, want)

    _property()
