"""Autotuner: action->candidate mapping, generated-spec surgery, the loop."""

import json

import numpy as np
import pytest

from repro.core.advisor import Action
from repro.core.collector import KernelSpec, OperandSpec, analyze
from repro.core.patterns import MISALIGNMENT, detect_all
from repro.core.session import ARTIFACT_VERSION, ProfileSession
from repro.core.trace import GridSampler
from repro.core.tuner import (
    VMEM_PIN_BUDGET_BYTES,
    TuneError,
    align_spec,
    candidates_for_action,
    drop_scratch_spec,
    ladder_candidates,
    pin_spec,
    retile_spec,
    transpose_spec,
    tune,
    tune_all,
    trajectories_from_session,
)

FULL = GridSampler(None)


def _action(kind, region, pattern="hot", saving=0.5, params=()):
    return Action(
        kind=kind,
        region=region,
        pattern=pattern,
        description="synthetic",
        est_transaction_saving=saving,
        params=params,
    )


# -- every Action.kind produces at least one candidate -----------------------


@pytest.mark.parametrize(
    "kind,pattern,region,spec_fn",
    [
        ("retile", "false-sharing", "C",
         lambda: __import__("repro.kernels.gemm", fromlist=["x"])
         .gemm_v00_spec(256, 256, 256)),
        ("vmem_pin", "hot", "B",
         lambda: __import__("repro.kernels.gemm", fromlist=["x"])
         .gemm_v00_spec(256, 256, 256)),
        ("reorder_grid", "hot-random", "x",
         lambda: __import__("repro.kernels.spmv", fromlist=["x"])
         .spmv_csr_spec(8192, 4096)),
        ("pad_align", "misalignment", "rowOffsets_shift1",
         lambda: __import__("repro.kernels.spmv", fromlist=["x"])
         .spmv_csr_spec(8192, 4096)),
        ("drop_scratch", "scratch-abuse", "Y_shr",
         lambda: __import__("repro.kernels.ttm", fromlist=["x"])
         .ttm_scratch_spec(512, 8, 32)),
        ("transpose", "strided", "q",
         lambda: __import__("repro.kernels.gramschm", fromlist=["x"])
         .k3_naive_block_spec(512, 512, 512, k=3)),
        # 1-D data-dependent strided region: falls back to the pin/stage fix
        ("transpose", "strided", "q",
         lambda: __import__("repro.kernels.gramschm", fromlist=["x"])
         .k3_naive_spec(512, 512, 512, k=3)),
    ],
)
def test_every_action_kind_yields_a_candidate(kind, pattern, region, spec_fn):
    spec = spec_fn()
    cands = candidates_for_action(_action(kind, region, pattern), spec)
    assert cands, f"{kind} produced no candidate for {region}"
    for c in cands:
        built, _ctx = c.build()
        assert isinstance(built, KernelSpec)
        assert built.source is None  # generated specs are not registry refs
        # every generated candidate must actually be collectable
        hm = analyze(built, sampler=FULL)
        assert hm.sector_transactions() >= 0


def test_candidates_carry_action_provenance():
    from repro.kernels.gemm import gemm_v00_spec

    act = _action("retile", "C", "false-sharing", saving=0.9)
    (cand, *_rest) = candidates_for_action(act, gemm_v00_spec(256, 256, 256))
    prov = cand.provenance()
    json.dumps(prov)  # JSON-ready end to end
    assert prov["action"]["kind"] == "retile"
    assert prov["action"]["region"] == "C"
    assert prov["source"] == "generated"
    assert cand.predicted_saving == act.est_transaction_saving


# -- generated-spec surgery is exact ----------------------------------------


def test_retile_matches_handwritten_v01():
    """The generated retile of gemm v00 is the hand-written v01 fix."""
    from repro.kernels.gemm import gemm_v00_spec, gemm_v01_spec

    retiled = retile_spec(gemm_v00_spec(512, 512, 512), "C", 8)
    assert retiled is not None
    assert retiled.grid == (64,)
    hm_gen = analyze(retiled, sampler=FULL)
    hm_ref = analyze(gemm_v01_spec(512, 512, 512, bm=8), sampler=FULL)
    assert hm_gen.sector_transactions() == hm_ref.sector_transactions()
    for ra, rb in zip(hm_gen.regions, hm_ref.regions):
        assert np.array_equal(ra.tags_array, rb.tags_array)
        assert np.array_equal(ra.sector_temps_array, rb.sector_temps_array)


def test_retile_refuses_unknown_region_and_exotic_maps():
    from repro.kernels.gemm import gemm_v00_spec

    spec = gemm_v00_spec(256, 256, 256)
    assert retile_spec(spec, "nope", 8) is None
    assert retile_spec(spec, "C", 3) is None  # 256 % 3 != 0
    # a strided (non-identity) map cannot be certified -> refused
    import dataclasses

    strided = dataclasses.replace(
        spec,
        operands=tuple(
            dataclasses.replace(op, index_map=lambda i: (2 * i, 0))
            if op.name == "C"
            else op
            for op in spec.operands
        ),
    )
    assert retile_spec(strided, "C", 8) is None


def test_align_spec_fixes_misalignment():
    from repro.kernels.spmv import spmv_csr_spec

    spec = spmv_csr_spec(8192, 4096)
    before = analyze(spec, sampler=FULL)
    assert any(
        r.pattern == MISALIGNMENT and r.region == "rowOffsets_shift1"
        for r in detect_all(before)
    )
    aligned = align_spec(spec, "rowOffsets_shift1")
    assert aligned is not None
    after = analyze(aligned, sampler=FULL, dynamic_context=None)
    assert not any(
        r.pattern == MISALIGNMENT and r.region == "rowOffsets_shift1"
        for r in detect_all(after)
    )
    assert after.sector_transactions() < before.sector_transactions()
    # aligning an already-aligned region is not a candidate
    assert align_spec(spec, "rowOffsets") is None


def test_drop_scratch_removes_the_region():
    from repro.kernels.ttm import ttm_scratch_spec

    spec = ttm_scratch_spec(512, 8, 32)
    dropped = drop_scratch_spec(spec, "Y_shr")
    assert dropped is not None and dropped.scratch == ()
    hm = analyze(dropped, sampler=FULL)
    assert "Y_shr" not in hm.region_names()
    assert drop_scratch_spec(spec, "vals") is None  # not a scratch buffer


def test_pin_only_loads_within_vmem_budget():
    from repro.kernels.gemm import gemm_v00_spec

    spec = gemm_v00_spec(256, 256, 256)
    pinned = pin_spec(spec, "B")
    assert pinned is not None
    b = next(o for o in pinned.operands if o.name == "B")
    assert b.once
    hm = analyze(pinned, sampler=FULL)
    assert hm.sector_transactions() < analyze(
        spec, sampler=FULL
    ).sector_transactions()
    # stores are not pinnable: they must cross back to HBM
    assert pin_spec(spec, "C") is None
    # an operand bigger than VMEM is not pinnable
    n = int(np.sqrt(VMEM_PIN_BUDGET_BYTES / 4)) + 256
    big = KernelSpec(
        name="big",
        grid=(4,),
        operands=(
            OperandSpec("W", (n, n), np.float32, (n, n), lambda i: (0, 0)),
        ),
    )
    assert pin_spec(big, "W") is None


def test_transpose_turns_column_block_into_row_block():
    from repro.kernels.gramschm import k3_naive_block_spec

    spec = k3_naive_block_spec(512, 512, 512, k=3)
    t = transpose_spec(spec, "q")
    assert t is not None
    q = next(o for o in t.operands if o.name == "q")
    assert q.shape == (512, 512) and q.block_shape == (1, 512)
    before = analyze(spec, sampler=FULL)
    after = analyze(t, sampler=FULL)
    assert after.sector_transactions("q") < before.sector_transactions("q")


# -- ladder candidates round-trip through the registry -----------------------


def test_ladder_candidates_round_trip_kernels_build():
    from repro import kernels as kreg

    for name in kreg.names():
        entry = kreg.get(name)
        cands = ladder_candidates(entry, frozenset(), [], min_position=0)
        assert len(cands) == sum(
            1 for v in entry.variants if v.role == "optimized"
        )
        for c in cands:
            assert c.ref and c.source == "ladder"
            spec, _ctx = c.build()  # rebuilds through kernels.build
            spec2, _ = kreg.build(c.ref)
            from repro.core.collector import _spec_fingerprint

            assert _spec_fingerprint(spec) == _spec_fingerprint(spec2)
            assert spec.source == c.ref  # shard workers can rebuild it


def test_ladder_is_walked_forward():
    from repro import kernels as kreg

    entry = kreg.get("gemm")
    cands = ladder_candidates(entry, frozenset(), [], min_position=2)
    assert [c.variant for c in cands] == ["v02"]  # v01 is behind the floor


# -- the loop ----------------------------------------------------------------


def test_tune_closes_the_loop_on_gemm():
    res = tune("gemm", budget=4, seed=0)
    assert res.improved
    assert res.final.tx_after < res.final.tx_before
    assert res.fixed_patterns  # a fixed-pattern final verdict
    assert res.best.transactions == res.final.tx_after
    assert 1 <= len(res.steps) <= 4
    assert res.steps[0].candidate.label == "ladder:v01"  # ladder order
    json.dumps(res.as_dict())  # BENCH_tune.json row is JSON-ready
    assert "tune: gemm" in res.summary()


def test_tune_is_deterministic_under_a_fixed_seed():
    a = tune("gemm", budget=3, seed=123)
    b = tune("gemm", budget=3, seed=123)
    assert [s.candidate.label for s in a.steps] == [
        s.candidate.label for s in b.steps
    ]
    assert [s.accepted for s in a.steps] == [s.accepted for s in b.steps]
    assert [s.transactions for s in a.steps] == [
        s.transactions for s in b.steps
    ]
    assert a.ranked()[0].candidate.label == b.ranked()[0].candidate.label


def test_tune_budget_zero_returns_baseline():
    res = tune("gemm", budget=0)
    assert res.steps == ()
    assert res.best_label == "baseline"
    assert not res.improved and not res.converged


def test_tune_target_pattern_filters_actions():
    res = tune("gemm", budget=2, target_patterns=["false-sharing"])
    # the ladder fixes false sharing in one step; the hot-B pattern is
    # out of scope, so the run converges without chasing it
    assert res.improved and res.converged
    assert all(p == "false-sharing" for _r, p in res.fixed_patterns)


def test_tune_scratch_abuse_accepted_at_equal_traffic():
    # ttm's fix keeps HBM traffic identical; the tuner must still accept
    # it (pattern gone, scratch traffic gone) and report it as fixed
    res = tune("ttm", budget=2)
    assert not res.improved  # equal HBM transfers by design
    assert ("Y_shr", "scratch-abuse") in res.fixed_patterns
    assert res.best_label != "baseline"


def test_tune_unknown_kernel_raises():
    from repro.core.tuner import TuneError

    with pytest.raises(TuneError):
        tune("definitely-not-a-kernel")


# -- session persistence ------------------------------------------------------


def test_tune_persists_trajectory_with_provenance(tmp_path):
    sess = ProfileSession(tmp_path / "sess")
    res = sess.tune("gramschm", budget=2)
    names = sess.iteration_names()
    assert len(names) == 1 + len(res.steps)
    # baseline iteration carries step-0 provenance
    it0 = sess.iteration(0)
    assert it0.tuning["role"] == "baseline"
    assert it0.tuning["family"] == "gramschm"
    # candidate iterations record which Action spawned which candidate
    it1 = sess.iteration(1)
    assert it1.tuning["role"] == "candidate"
    cand = it1.tuning["candidate"]
    assert cand["label"] == res.steps[0].candidate.label
    assert cand["action"] is not None and "kind" in cand["action"]
    assert it1.tuning["verdict"] == res.steps[0].diff.verdict
    # the manifest stamps the current version and is JSON all the way down
    manifest = json.loads((it1.path / "manifest.json").read_text())
    assert manifest["version"] == ARTIFACT_VERSION == 6
    assert manifest["tuning"]["candidate"]["label"] == cand["label"]
    # a later process recovers the whole trajectory from disk alone
    (traj,) = trajectories_from_session(
        ProfileSession(tmp_path / "sess", create=False)
    )
    assert traj["kernel"] == "gramschm"
    assert traj["improved"] == res.improved
    assert traj["baseline"]["transactions"] == res.baseline.transactions
    assert traj["best"]["transactions"] == res.best.transactions
    assert len(traj["steps"]) == len(res.steps)


def test_retuning_same_family_yields_separate_trajectories(tmp_path):
    """Two tune runs into one session must not merge into one garbled
    trajectory: each run is keyed by its baseline iteration."""
    sess = ProfileSession(tmp_path / "sess")
    r1 = sess.tune("ttm", budget=1)
    r2 = sess.tune("ttm", budget=1)
    trajs = trajectories_from_session(
        ProfileSession(tmp_path / "sess", create=False)
    )
    assert len(trajs) == 2
    assert [t["kernel"] for t in trajs] == ["ttm", "ttm"]
    assert trajs[0]["run"] != trajs[1]["run"]
    for traj, res in zip(trajs, (r1, r2)):
        assert traj["candidates_tried"] == len(res.steps)
        assert traj["baseline"]["transactions"] == res.baseline.transactions
        assert traj["best"]["transactions"] == res.best.transactions
    # the best iteration link points at an accepted step (or baseline)
    assert trajs[0]["best"]["iteration"] in {
        s["iteration"] for s in trajs[0]["steps"] if s["accepted"]
    } | {trajs[0]["baseline"]["iteration"]}


def test_classify_rejects_prefix_identity_maps():
    """A map that is identity only on a prefix must not certify."""
    import dataclasses

    from repro.kernels.gemm import gemm_v00_spec

    spec = gemm_v00_spec(256, 256, 256)
    piecewise = dataclasses.replace(
        spec,
        operands=tuple(
            dataclasses.replace(op, index_map=lambda i: (min(int(i), 7), 0))
            if op.name == "C"
            else op
            for op in spec.operands
        ),
    )
    assert retile_spec(piecewise, "C", 8) is None


def test_non_tuned_iterations_have_no_tuning(tmp_path):
    from repro.kernels.gemm import gemm_v00_spec

    sess = ProfileSession(tmp_path / "sess")
    it = sess.profile([gemm_v00_spec(128, 128, 128)])
    assert it.tuning is None
    assert trajectories_from_session(sess) == []


# -- the concurrent tune scheduler -------------------------------------------


def test_tune_all_single_family_matches_serial():
    """With one family the scheduler degenerates to the serial loop."""
    serial = tune("gramschm", budget=3, seed=7)
    sched = tune_all(["gramschm"], budget=3, seed=7)
    (res,) = sched.results
    assert [s.candidate.label for s in res.steps] == [
        s.candidate.label for s in serial.steps
    ]
    assert [s.accepted for s in res.steps] == [
        s.accepted for s in serial.steps
    ]
    assert res.best_label == serial.best_label
    assert res.best.transactions == serial.best.transactions


def test_tune_all_matches_serial_when_budget_ample():
    """Ordered result commitment: each family's trajectory is the one
    serial ``tune`` produces, as long as the global budget never cuts a
    family short (both converge well under 10 candidates)."""
    sched = tune_all(["gramschm", "ttm"], budget=10, seed=0)
    for res in sched.results:
        assert res.converged
        serial = tune(res.kernel, budget=10, seed=0)
        assert [s.candidate.label for s in res.steps] == [
            s.candidate.label for s in serial.steps
        ]
        assert res.best.transactions == serial.best.transactions


def test_tune_all_is_deterministic_per_seed():
    a = tune_all(["gramschm", "ttm"], budget=4, seed=42)
    b = tune_all(["gramschm", "ttm"], budget=4, seed=42)
    sig = lambda r: [  # noqa: E731
        (s.candidate.label, s.accepted, s.transactions) for s in r.steps
    ]
    assert [sig(r) for r in a.results] == [sig(r) for r in b.results]
    assert a.spent == b.spent and a.rounds == b.rounds


def test_tune_all_enforces_one_global_budget():
    """budget=2 across two families: one candidate each (round-robin in
    family order), baselines excluded from the count."""
    res = tune_all(["gramschm", "ttm"], budget=2, seed=0)
    assert res.spent == 2
    assert [len(r.steps) for r in res.results] == [1, 1]
    assert res.rounds == 1


def test_tune_all_budget_zero_profiles_baselines_only():
    res = tune_all(["gramschm", "ttm"], budget=0, seed=0)
    assert res.spent == 0
    assert all(not r.steps for r in res.results)
    assert all(r.best_label == "baseline" for r in res.results)


def test_tune_all_empty_family_list_raises():
    with pytest.raises(TuneError):
        tune_all([], budget=2)


def test_tune_all_persists_linked_provenance(tmp_path):
    """Session iterations commit in family order with baseline links,
    and every step records the iteration that stored it."""
    sess = ProfileSession(tmp_path / "sess")
    res = tune_all(["gramschm", "ttm"], budget=2, seed=0, session=sess)
    # 2 baselines + 2 candidates, committed deterministically
    assert sess.iteration_names() == ["iter0", "iter1", "iter2", "iter3"]
    assert sess.iteration(0).tuning["family"] == "gramschm"
    assert sess.iteration(1).tuning["family"] == "ttm"
    for r in res.results:
        assert r.baseline_iteration
        for s in r.steps:
            assert s.iteration  # the satellite fix: never ""
            it = sess.iteration(sess.iteration_names().index(s.iteration))
            assert it.tuning["baseline"] == r.baseline_iteration
            assert it.tuning["candidate"]["label"] == s.candidate.label
    trajs = trajectories_from_session(
        ProfileSession(tmp_path / "sess", create=False)
    )
    assert sorted(t["kernel"] for t in trajs) == ["gramschm", "ttm"]
    for traj in trajs:
        assert all(s["iteration"] for s in traj["steps"])


def test_tune_all_shared_cache_bounds_fresh_traces(tmp_path):
    """A repeated tune --all run re-traces nothing: every profile
    (baselines included) is served from the shared cache."""
    from repro.core.cache import CollectionCache

    cache = CollectionCache(tmp_path / "cache")
    tune_all(["gramschm", "ttm"], budget=2, seed=0, cache=cache)
    before_miss = cache.stats.misses
    res = tune_all(["gramschm", "ttm"], budget=2, seed=0, cache=cache)
    fresh = cache.stats.misses - before_miss
    profiles = res.spent + len(res.results)  # candidates + baselines
    assert fresh == 0
    assert cache.stats.hits >= profiles
