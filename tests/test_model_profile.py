"""Whole-model profiling: interception, discovery, per-layer rollup.

Covers the ``repro.core.model_profile`` walker (the ``cuthermo model``
engine): the kernel-call interception shim, per-layer discovery with
source-stamped specs, the backward kind-swap model, the v5 layer-table
partition invariant (property-tested: for ANY partition of the profiled
kernels into layers, per-layer totals sum to the iteration total — and
``_validate_layers`` rejects everything that is not a partition), the
``model.<model>.<kind>`` registry bridge, and one end-to-end
``profile_model`` run persisting a v5 artifact.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import model_profile as mp
from repro.core.heatmap import Heatmap, RegionHeatmap
from repro.core.session import (
    SessionError,
    _validate_layers,
    heatmaps_equal,
    load_iteration,
)
from repro.core.tiles import TileGeometry
from repro.core.trace import RegionInfo
from repro.models.registry import MODELS, get_model, kind_spec


# ---------------------------------------------------------------------------
# interception shim
# ---------------------------------------------------------------------------


def test_intercept_records_only_scoped_builds():
    from repro.kernels import gemm

    original = gemm.gemm_v01_spec
    with mp.intercept() as calls:
        gemm.gemm_v01_spec(16, 16, 16, bm=8)  # no scope: invisible
        assert calls == []
        with mp.layer_scope("layer0"):
            spec = gemm.gemm_v01_spec(16, 16, 16, bm=8)
        gemm.gemm_v01_spec(16, 16, 16, bm=8)  # scope closed again
    assert len(calls) == 1
    (call,) = calls
    assert call.layer == "layer0"
    assert call.entry == "repro.kernels.gemm:gemm_v01_spec"
    assert call.spec == spec
    # the monkeypatch is fully restored
    assert gemm.gemm_v01_spec is original


def test_intercept_restores_on_error():
    from repro.kernels import flash, gemm, gmm, ssd

    before = {
        (m.__name__, f): getattr(m, f)
        for m, f in ((flash, "flash_spec"), (gemm, "gemm_v01_spec"),
                     (gemm, "gemm_v02_spec"), (gmm, "gmm_spec"),
                     (ssd, "ssd_chunk_spec"))
    }
    with pytest.raises(RuntimeError):
        with mp.intercept():
            raise RuntimeError("boom")
    for (mod_name, fn_name), fn in before.items():
        mod = __import__(mod_name, fromlist=[fn_name])
        assert getattr(mod, fn_name) is fn, (mod_name, fn_name)


def test_nested_layer_scopes_attribute_innermost():
    from repro.kernels import gemm

    with mp.intercept() as calls:
        with mp.layer_scope("outer"):
            with mp.layer_scope("inner"):
                gemm.gemm_v01_spec(16, 16, 16, bm=8)
            gemm.gemm_v01_spec(16, 16, 16, bm=8)
    assert [c.layer for c in calls] == ["inner", "outer"]


# ---------------------------------------------------------------------------
# discovery
# ---------------------------------------------------------------------------


def test_discover_transformer_tiny_layers_and_stamps():
    entry = get_model("transformer-tiny")
    found = mp.discover(
        "transformer-tiny", entry.config, entry.batch, entry.seq
    )
    assert [(d.name, d.layer, d.kind) for d in found] == [
        ("layer0.attn", "layer0", "attn"),
        ("layer0.mlp", "layer0", "mlp"),
        ("layer1.attn", "layer1", "attn"),
        ("layer1.mlp", "layer1", "mlp"),
        ("head.unembed", "head", "unembed"),
    ]
    for d in found:
        assert d.family == f"model.transformer-tiny.{d.kind}"
        # default shapes: specs carry the registry string ref a shard
        # worker can rebuild from
        assert isinstance(d.spec.source, str)
        assert d.spec.source.startswith(d.family + ":")
        # the spec is exactly what the derivation builds
        want = kind_spec(entry.config, d.kind, entry.batch, entry.seq)
        assert d.spec.name == want.name
        assert d.spec.grid == want.grid


def test_discover_with_non_default_shapes_uses_builder_triples():
    entry = get_model("transformer-tiny")
    cfg = dataclasses.replace(entry.config, d_ff=512)
    found = mp.discover(
        "transformer-tiny", cfg, entry.batch, entry.seq,
        default_shapes=False,
    )
    for d in found:
        fn_ref, args, kwargs = d.spec.source
        assert fn_ref == "repro.models.registry:kind_spec"
        assert args == (cfg, d.kind, entry.batch, entry.seq)
        assert kwargs == {"rung": 0}


def test_discover_backward_appends_kind_swapped_mirrors():
    entry = get_model("mamba-tiny")
    found = mp.discover(
        "mamba-tiny", entry.config, entry.batch, entry.seq, backward=True
    )
    fwd = [d for d in found if not d.backward]
    bwd = [d for d in found if d.backward]
    assert len(fwd) == len(bwd) == 3
    assert [d.name for d in bwd] == [f"{d.name}.bwd" for d in fwd]
    for f, b in zip(fwd, bwd):
        assert b.spec.name == f.spec.name + "_bwd"
        flipped = {"load": "store", "store": "load"}
        for fop, bop in zip(f.spec.operands, b.spec.operands):
            assert bop.kind == flipped.get(fop.kind, fop.kind), fop.name
        # backward specs rebuild through the module-level bwd_spec triple
        assert b.spec.source[0] == "repro.core.model_profile:bwd_spec"


def test_bwd_spec_preserves_scratch():
    entry = get_model("transformer-tiny")
    fwd = kind_spec(entry.config, "attn", entry.batch, entry.seq)
    bwd = mp.bwd_spec(entry.config, "attn", entry.batch, entry.seq)
    assert bwd.scratch == fwd.scratch  # accumulators are direction-free
    assert bwd.grid == fwd.grid


# ---------------------------------------------------------------------------
# the rollup partition invariant
# ---------------------------------------------------------------------------


def _fake_profiled(name, sector_temps):
    """A minimal ProfiledKernel whose transactions == sum(sector_temps)."""
    from repro.core.session import ProfiledKernel

    temps = np.asarray(sector_temps, dtype=np.int64)
    region = RegionHeatmap(
        RegionInfo(
            name="x",
            geometry=TileGeometry((16, 128), itemsize=4, name="x"),
            space="hbm",
        ),
        n_programs=1,
        tags=np.arange(temps.size, dtype=np.int64) * 8,
        word_temps=np.zeros((temps.size, 8), dtype=np.int64),
        sector_temps=temps,
    )
    hm = Heatmap(
        kernel=name, grid=(1,), sampler="full", regions=(region,),
        n_records=1, dropped=0,
    )
    return ProfiledKernel(
        name=name, variant="v00", heatmap=hm, reports=(), actions=()
    )


def _rows_from_partition(kernels, assignment):
    """Build a layer table from a kernel->layer assignment mapping."""
    rows = {}
    for pk in kernels:
        layer = assignment[pk.name]
        row = rows.setdefault(
            layer,
            {"path": layer, "kinds": [], "kernels": [], "transactions": 0,
             "patterns": []},
        )
        row["kernels"].append(pk.name)
        row["transactions"] += pk.transactions
    return list(rows.values())


def test_rollup_sums_to_iteration_total_for_any_partition():
    """Property: any partition of kernels into layers validates, and its
    per-layer totals sum exactly to the iteration total."""
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    given, settings = hypothesis.given, hypothesis.settings

    @settings(max_examples=50, deadline=None)
    @given(
        temps=st.lists(
            st.lists(st.integers(min_value=0, max_value=9), min_size=1,
                     max_size=4),
            min_size=1,
            max_size=6,
        ),
        layer_of=st.lists(st.integers(min_value=0, max_value=3), min_size=6,
                          max_size=6),
    )
    def _property(temps, layer_of):
        kernels = [
            _fake_profiled(f"k{i}", t) for i, t in enumerate(temps)
        ]
        assignment = {
            pk.name: f"layer{layer_of[i]}" for i, pk in enumerate(kernels)
        }
        table = _rows_from_partition(kernels, assignment)
        layers = {"model": "prop", "table": table}
        _validate_layers(layers, kernels)  # any true partition passes
        rollup = sum(row["transactions"] for row in table)
        assert rollup == sum(pk.transactions for pk in kernels)

    _property()


def test_rollup_partition_deterministic_fallback():
    # the hypothesis property, pinned on three fixed partitions so the
    # invariant stays covered when hypothesis is not installed
    kernels = [
        _fake_profiled("k0", [2, 3]),
        _fake_profiled("k1", [5]),
        _fake_profiled("k2", [1, 1, 1]),
    ]
    total = sum(pk.transactions for pk in kernels)
    assert total == 13
    partitions = [
        {"k0": "a", "k1": "a", "k2": "a"},  # everything in one layer
        {"k0": "a", "k1": "b", "k2": "c"},  # one kernel per layer
        {"k0": "a", "k1": "b", "k2": "a"},  # mixed
    ]
    for assignment in partitions:
        table = _rows_from_partition(kernels, assignment)
        _validate_layers({"table": table}, kernels)
        assert sum(row["transactions"] for row in table) == total


def test_validate_layers_rejects_non_partitions():
    kernels = [_fake_profiled("k0", [2]), _fake_profiled("k1", [3])]
    ok = _rows_from_partition(kernels, {"k0": "a", "k1": "a"})

    with pytest.raises(SessionError, match="'table'"):
        _validate_layers({}, kernels)
    with pytest.raises(SessionError, match="malformed layer row"):
        _validate_layers({"table": [{"path": "a"}]}, kernels)
    with pytest.raises(SessionError, match="not.*profiled"):
        bad = [dict(ok[0], kernels=["k0", "k1", "ghost"])]
        _validate_layers({"table": bad}, kernels)
    with pytest.raises(SessionError, match="both layer"):
        dup = [dict(ok[0]), dict(ok[0], path="b")]
        _validate_layers({"table": dup}, kernels)
    with pytest.raises(SessionError, match="sum to"):
        wrong = [dict(ok[0], transactions=99)]
        _validate_layers({"table": wrong}, kernels)
    with pytest.raises(SessionError, match="missing from the layer"):
        short = _rows_from_partition(kernels[:1], {"k0": "a"})
        _validate_layers({"table": short}, kernels)


def test_layers_table_matches_discovery_order():
    entry = get_model("transformer-tiny")
    found = mp.discover(
        "transformer-tiny", entry.config, entry.batch, entry.seq
    )
    profiled = [
        _fake_profiled(d.name, [i + 1]) for i, d in enumerate(found)
    ]
    table = mp.layers_table(found, profiled)
    assert [row["path"] for row in table] == ["layer0", "layer1", "head"]
    assert table[0]["kernels"] == ["layer0.attn", "layer0.mlp"]
    assert table[0]["kinds"] == ["attn", "mlp"]
    assert table[0]["transactions"] == 1 + 2
    _validate_layers({"table": table}, profiled)


# ---------------------------------------------------------------------------
# the model.<model>.<kind> registry bridge
# ---------------------------------------------------------------------------


def test_model_refs_resolve_through_kernel_registry():
    from repro import kernels as kreg

    entry = kreg.get("model.transformer-tiny.mlp")
    assert entry.name == "model.transformer-tiny.mlp"
    assert [v.role for v in entry.variants] == ["baseline", "optimized"]
    spec, ctx = kreg.build("model.transformer-tiny.mlp")
    assert ctx is None
    assert spec.source == "model.transformer-tiny.mlp:v01"
    # the optimized rung builds too, with its own stamp
    spec2, _ = kreg.build("model.transformer-tiny.mlp:v02")
    assert spec2.source == "model.transformer-tiny.mlp:v02"
    # model families are derived, not listed: the static registry
    # surface (tune --all's default scope) must not grow
    assert not any(n.startswith("model.") for n in kreg.names())


def test_model_refs_reject_unknowns():
    from repro import kernels as kreg

    with pytest.raises(KeyError):
        kreg.get("model.transformer-tiny")  # malformed: no kind
    with pytest.raises(KeyError):
        kreg.get("model.nope.mlp")  # unknown model
    with pytest.raises(KeyError):
        kreg.get("model.mamba-tiny.mlp")  # kind the layout doesn't use


def test_model_refs_lint_cleanly_enough_to_tune():
    # lint must accept model-derived refs (the tuner pre-screen relies
    # on it); statically priced, no kernel runs
    from repro.core.lint import lint_ref

    for ref in ("model.transformer-tiny.mlp:v01",
                "model.transformer-tiny.mlp:v02",
                "model.mamba-tiny.ssm:chunk"):
        rep = lint_ref(ref)
        assert rep.static_transactions is not None, ref
        assert not any(f.pattern == "nonaffine" for f in rep.findings), ref


def test_every_model_kind_has_a_ladder_improvement_or_single_rung():
    # the tune-acceptance precondition: for each registered model and
    # kind, the optimized rung (when one exists) strictly lowers the
    # statically priced transfer count
    from repro import kernels as kreg
    from repro.core.lint import lint_ref

    for model_name in MODELS:
        from repro.models.registry import kernel_kinds

        for kind in kernel_kinds(MODELS[model_name].config):
            entry = kreg.get(f"model.{model_name}.{kind}")
            costs = []
            for v in entry.variants:
                rep = lint_ref(f"{entry.name}:{v.name}")
                assert rep.static_transactions is not None
                costs.append(rep.static_transactions)
            if len(costs) > 1:
                assert min(costs[1:]) < costs[0], (model_name, kind, costs)


# ---------------------------------------------------------------------------
# end to end: profile_model persists a v5 artifact
# ---------------------------------------------------------------------------


def test_profile_model_end_to_end(tmp_path):
    it = mp.profile_model(
        "mamba-tiny", tmp_path / "sess", hlo=False
    )
    assert it.layers is not None
    assert it.layers["model"] == "mamba-tiny"
    assert "hlo" not in it.layers
    table = it.layers["table"]
    assert [row["path"] for row in table] == ["layer0", "layer1", "head"]
    rollup = sum(row["transactions"] for row in table)
    total = mp.iteration_transactions(it)
    assert rollup == total > 0
    # the artifact round-trips: reload and compare bit-for-bit
    again = load_iteration(it.path)
    assert again.layers == it.layers
    for a, b in zip(it.kernels, again.kernels):
        assert heatmaps_equal(a.heatmap, b.heatmap)
