"""Extensions coverage: 2-axis EP, cache writes, constraint context, render."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -- seq-buffer writes (the decode cache path) --------------------------------


def test_update_seq_buffer_onehot_matches_dus():
    from repro.models.attention import update_seq_buffer

    buf = jnp.zeros((2, 8, 3, 4))
    new = jnp.ones((2, 1, 3, 4)) * 7
    for idx in (0, 3, 7):
        got = update_seq_buffer(buf, new, jnp.asarray(idx))
        want = jax.lax.dynamic_update_slice(buf, new, (0, idx, 0, 0))
        np.testing.assert_array_equal(got, want)


def test_update_seq_buffer_full_replace():
    from repro.models.attention import update_seq_buffer

    buf = jnp.zeros((2, 4, 3))
    new = jnp.ones((2, 4, 3))
    got = update_seq_buffer(buf, new, jnp.asarray(0))
    np.testing.assert_array_equal(got, new)


def test_update_seq_buffer_partial_dus_fallback():
    from repro.models.attention import update_seq_buffer

    buf = jnp.zeros((1, 8, 2))
    new = jnp.ones((1, 3, 2))
    got = update_seq_buffer(buf, new, jnp.asarray(2))
    assert float(got[0, 1].sum()) == 0 and float(got[0, 2].sum()) == 2
    assert float(got[0, 4].sum()) == 2 and float(got[0, 5].sum()) == 0


# -- constraint context ---------------------------------------------------------


def test_constrain_logical_noop_without_rules():
    from repro.parallel.context import constrain_logical

    x = jnp.ones((4, 4))
    assert constrain_logical(x, ("act_batch", None)) is x


def test_constrain_logical_annotates_under_mesh():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, json
from repro.launch.mesh import mesh_axis_types
from repro.parallel.context import use_rules, constrain_logical
from repro.parallel.sharding import make_rules
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     **mesh_axis_types(2))
rules = make_rules()
with mesh, use_rules(rules):
    def f(x):
        return constrain_logical(x, ("act_batch", None, "vocab")) * 2
    txt = jax.jit(f).lower(jax.ShapeDtypeStruct((8, 4, 64), jnp.float32)).as_text()
print(json.dumps({"annotated": ("sdy.sharding" in txt) or ("mhlo.sharding" in txt)
                 or ("Sharding" in txt)}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["annotated"]


def test_ep_two_axis_expert_sharding_parity():
    """Experts over ("model","data") — device-local experts — must match
    the dense oracle exactly."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, json
from repro.launch.mesh import mesh_axis_types
from repro.models.moe import MoEConfig, moe_defs, moe_apply_ep, moe_ref
from repro.models.params import init_params
from repro.parallel.context import use_rules
from repro.parallel.sharding import make_rules
cfg = MoEConfig(d_model=16, d_ff=32, n_experts=8, top_k=2,
                capacity_factor=8.0, moe_impl="ep")
params = init_params(moe_defs(cfg), jax.random.key(0))
x = jax.random.normal(jax.random.key(1), (4, 8, 16))
y_ref, _ = moe_ref(params, x, cfg)
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     **mesh_axis_types(2))
rules = make_rules(expert_axes=("model", "data"))  # 8 experts over 8 chips
with mesh, use_rules(rules):
    y, aux = jax.jit(lambda p, x: moe_apply_ep(p, x, cfg))(params, x)
print(json.dumps({"diff": float(jnp.abs(y - y_ref).max())}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["diff"] < 1e-4


# -- renderers -------------------------------------------------------------------


def _toy_heatmap():
    from repro.core import analyze
    from repro.core.trace import GridSampler
    from repro.kernels.gemm import gemm_v00_spec

    return analyze(gemm_v00_spec(256, 256, 256), GridSampler((0,), window=32))


def test_render_csv_roundtrip_counts():
    from repro.core.render import render_csv

    hm = _toy_heatmap()
    text = render_csv(hm, compress=True)
    rows = [l for l in text.splitlines() if l and not l.startswith("region,")]
    # sum of repeats per region == touched sectors
    per_region = {}
    for row in rows:
        parts = row.split(",")
        per_region[parts[0]] = per_region.get(parts[0], 0) + int(parts[2])
    for rh in hm.regions:
        assert per_region[rh.region.name] == rh.touched_sectors


def test_render_html_and_ascii():
    from repro.core.render import render_ascii, render_html

    hm = _toy_heatmap()
    html = render_html(hm)
    assert "<table>" in html and hm.kernel in html
    ascii_ = render_ascii(hm, color=True, max_rows_per_region=4)
    assert "region A" in ascii_ and "sect" in ascii_


def test_save_heatmap(tmp_path):
    from repro.core.render import save

    hm = _toy_heatmap()
    save(hm, str(tmp_path / "hm.html"))
    save(hm, str(tmp_path / "hm.csv"))
    assert (tmp_path / "hm.html").stat().st_size > 100
    assert (tmp_path / "hm.csv").stat().st_size > 100


# -- sampler window ---------------------------------------------------------------


def test_grid_sampler_window_semantics():
    from repro.core.trace import GridSampler, sampled_grid

    s = GridSampler((0,), window=4)
    assert list(sampled_grid((16,), s)) == [(0,), (1,), (2,), (3,)]
    s1 = GridSampler((1,), window=4)
    assert list(sampled_grid((16,), s1)) == [(4,), (5,), (6,), (7,)]
    # 2-D: window applies to the last pinned coordinate
    s2 = GridSampler((0,), window=2)
    assert list(sampled_grid((4, 2), s2)) == [(0, 0), (0, 1), (1, 0), (1, 1)]
    assert "x4" in GridSampler((0,), window=4).describe()


# -- api facade --------------------------------------------------------------------


def test_api_report_and_actions():
    from repro.core import api
    from repro.core.trace import GridSampler
    from repro.kernels.gemm import gemm_v00_spec

    spec = gemm_v00_spec(256, 256, 256)
    rep = api.report(spec, GridSampler((0,), window=32))
    assert "thermo report" in rep and "false-sharing" in rep
    acts = api.actions(spec, GridSampler((0,), window=32))
    assert acts and acts[0].est_transaction_saving > 0
