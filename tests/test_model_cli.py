"""``cuthermo model`` end to end: subprocess exit contract + artifacts.

The whole-model subcommand is CI surface (the model-smoke job drives
it), so its 0/1/2 exit-code contract is pinned via subprocess like the
other gates: 0 profiled (and under budget), 1 the ``--max-transfers``
budget is blown, 2 unknown model / bad override.  The stored artifact
must be a current-version iteration whose per-layer rollup sums to the iteration
total and round-trips bit-identically; ``--report`` must render the
per-layer table.
"""

import json
import os
import subprocess
import sys

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.abspath(REPO_SRC)
        + os.pathsep
        + env.get("PYTHONPATH", "")
    )
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, "-m", "repro.cli", *args],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )


@pytest.fixture(scope="module")
def model_session(tmp_path_factory):
    """One profiled mamba-tiny session (cheapest registered model)."""
    sess = str(tmp_path_factory.mktemp("model") / "sess")
    proc = _run_cli(
        "model", "mamba-tiny", "--out", sess, "--no-hlo", "--report"
    )
    assert proc.returncode == 0, proc.stderr
    return sess, proc


def test_model_help_and_list():
    proc = _run_cli("model", "--help")
    assert proc.returncode == 0
    assert "--max-transfers" in proc.stdout
    proc = _run_cli("model", "--list")
    assert proc.returncode == 0
    for name in ("transformer-tiny", "moe-tiny", "mamba-tiny"):
        assert name in proc.stdout


def test_model_exit_0_prints_per_layer_table(model_session):
    sess, proc = model_session
    out = proc.stdout
    assert "# model mamba-tiny" in out
    for path in ("layer0", "layer1", "head", "total"):
        assert path in out
    assert os.path.isdir(os.path.join(sess, "iter0"))


def test_model_exit_2_on_unknown_model(tmp_path):
    proc = _run_cli("model", "no-such-model", "--out", str(tmp_path / "s"))
    assert proc.returncode == 2
    assert "unknown model" in proc.stderr


def test_model_exit_2_on_bad_override(tmp_path):
    proc = _run_cli(
        "model", "mamba-tiny", "-c", "bogus=1",
        "--out", str(tmp_path / "s"),
    )
    assert proc.returncode == 2
    assert "unknown config field" in proc.stderr
    proc = _run_cli(
        "model", "mamba-tiny", "-c", "n_layers", "--out", str(tmp_path / "s")
    )
    assert proc.returncode == 2
    assert "key=value" in proc.stderr


def test_model_exit_2_without_a_name():
    proc = _run_cli("model")
    assert proc.returncode == 2


def test_model_exit_1_when_budget_blown(tmp_path):
    # --max-transfers 0 is deterministic: any profile blows it, and the
    # artifact is still written before the gate fires
    sess = str(tmp_path / "s")
    proc = _run_cli(
        "model", "mamba-tiny", "--out", sess, "--no-hlo", "-q",
        "--max-transfers", "0",
    )
    assert proc.returncode == 1
    assert "budget blown" in proc.stderr
    assert os.path.isdir(os.path.join(sess, "iter0"))


def test_model_artifact_carries_layers_with_exact_rollup(model_session):
    sess, _ = model_session
    manifest = json.loads(
        open(os.path.join(sess, "iter0", "manifest.json")).read()
    )
    # current artifact version (v6: layers block + fault provenance)
    assert manifest["version"] == 6
    layers = manifest["layers"]
    assert layers["model"] == "mamba-tiny"
    rollup = sum(row["transactions"] for row in layers["table"])
    # acceptance criterion: per-layer totals sum EXACTLY to the total
    sys.path.insert(0, os.path.abspath(REPO_SRC))
    from repro.core.model_profile import iteration_transactions
    from repro.core.session import load_iteration

    it = load_iteration(os.path.join(sess, "iter0"))
    assert it.layers == layers
    assert rollup == iteration_transactions(it)
    # every kernel carries its model-family variant stamp
    assert all(pk.variant.startswith("model.mamba-tiny.")
               for pk in it.kernels)


def test_model_artifact_round_trips_bit_identically(model_session, tmp_path):
    sess, _ = model_session
    sys.path.insert(0, os.path.abspath(REPO_SRC))
    from repro.core.session import (
        heatmaps_equal,
        load_iteration,
        write_iteration,
    )

    it = load_iteration(os.path.join(sess, "iter0"))
    copy = tmp_path / "copy"
    write_iteration(copy, it.kernels, label=it.label, note=it.note,
                    layers=it.layers)
    again = load_iteration(copy)
    assert again.layers == it.layers
    for a, b in zip(it.kernels, again.kernels):
        assert heatmaps_equal(a.heatmap, b.heatmap)


def test_model_report_renders_per_layer_section(model_session):
    sess, _ = model_session
    md = open(os.path.join(sess, "iter0", "report", "report.md")).read()
    assert "## per-layer attribution — mamba-tiny" in md
    assert "| layer0 |" in md and "| **total** |" in md
    html = open(os.path.join(sess, "iter0", "report", "index.html")).read()
    assert "per-layer attribution" in html


def test_model_rerun_with_cache_is_bit_identical(tmp_path):
    # the model-smoke CI contract: a cached rerun serves hits and the
    # stored heat maps stay bit-identical with the uncached run
    sess = str(tmp_path / "s")
    cache = str(tmp_path / "cache")
    a = _run_cli("model", "mamba-tiny", "--out", sess, "--no-hlo", "-q",
                 "--cache", cache)
    assert a.returncode == 0, a.stderr
    b = _run_cli("model", "mamba-tiny", "--out", sess, "--no-hlo", "-q",
                 "--cache", cache)
    assert b.returncode == 0, b.stderr
    sys.path.insert(0, os.path.abspath(REPO_SRC))
    from repro.core.session import heatmaps_equal, load_iteration

    first = load_iteration(os.path.join(sess, "iter0"))
    second = load_iteration(os.path.join(sess, "iter1"))
    assert first.layers == second.layers
    for x, y in zip(first.kernels, second.kernels):
        assert heatmaps_equal(x.heatmap, y.heatmap)
