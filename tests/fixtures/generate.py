"""Deterministic generator for the committed artifact-format fixtures.

The golden artifacts under ``tests/fixtures/artifact-v{1..6}`` pin the
v1–v6 *load paths*: back-compat is guaranteed by files an old writer
could have produced, not just by code that rewrites today's format.
Each fixture is a tiny hand-built heat map (no kernel tracing, no jax)
written with the current writer and then rewritten to the target
version's manifest shape — exactly the keys that version's writer
emitted:

* v1 — no shard provenance, no tuning, no scratch_words metric
* v2 — shard provenance, no tuning, no scratch_words
* v3 — shard provenance + tuning provenance, no scratch_words
* v4 — v3 + the scratch_words metric, no layers attribution
* v5 — v4 + per-layer attribution (the ``layers`` manifest block)
* v6 — v5 + fault provenance (per-heatmap "faults" events and the
  top-level manifest "faults" block of a recovered collection)

Regenerate with ``python tests/fixtures/generate.py`` (from the repo
root, with ``src`` on PYTHONPATH); ``test_artifact_compat.py`` also
regenerates into a tmp dir and compares against the committed copies,
so generator drift fails loudly.  Everything is pinned (created=0.0,
wall_s=0.0, fixed temperatures), keeping regeneration deterministic.
"""

import json
import sys
from pathlib import Path

import numpy as np

from repro.core.heatmap import Heatmap, RegionHeatmap
from repro.core.resilience import FaultEvent
from repro.core.session import ProfiledKernel, write_iteration
from repro.core.tiles import TileGeometry
from repro.core.trace import RegionInfo, ShardInfo

FIXTURES = Path(__file__).parent

#: The tuning provenance stored in the v3+ fixtures (shape from
#: repro.core.tuner).
V3_TUNING = {
    "family": "golden",
    "run": "fixture",
    "step": 1,
    "role": "candidate",
    "candidate": {"label": "ladder:v01", "source": "ladder"},
    "accepted": True,
}

#: The per-layer attribution stored in the v5 fixture (shape from
#: ``cuthermo model``; must satisfy ``session._validate_layers``: the
#: single row claims the single kernel's 6 transactions).
V5_LAYERS = {
    "model": "golden-tiny",
    "batch": 1,
    "seq": 8,
    "overrides": [],
    "table": [
        {
            "path": "layer0",
            "kinds": ["gemm"],
            "kernels": ["golden"],
            "transactions": 6,
            "patterns": [["golden", "x", "hot"]],
        }
    ],
    "hlo": {
        "backward": False,
        "heat": {
            "collective_count": 0,
            "collective_bytes": 0.0,
            "bytes_by_op": {},
            "redundant": [],
        },
        "cost": {
            "flops": 64.0,
            "bytes": 512.0,
            "wire_bytes": 0.0,
            "by_collective": {},
        },
    },
}

#: Word temperatures of the fixture's HBM region: three sectors, eight
#: sublane rows each.  Row 0 is uniformly warm, row 1 touches a single
#: word, row 2 is cold except the tail — enough texture that pattern
#: detection has something to chew on without being huge.
_X_WORD_TEMPS = (
    (2, 2, 2, 2, 2, 2, 2, 2),
    (0, 0, 0, 3, 0, 0, 0, 0),
    (0, 0, 0, 0, 0, 0, 1, 1),
)
_X_SECTOR_TEMPS = (2, 3, 1)

_ACC_WORD_TEMPS = ((4, 4, 4, 4, 4, 4, 4, 4),)
_ACC_SECTOR_TEMPS = (4,)


def _region(name, space, word_temps, sector_temps):
    word_temps = np.asarray(word_temps, dtype=np.int64)
    return RegionHeatmap(
        RegionInfo(
            name=name,
            geometry=TileGeometry((16, 128), itemsize=4, name=name),
            space=space,
        ),
        n_programs=4,
        tags=np.arange(word_temps.shape[0], dtype=np.int64) * 8,
        word_temps=word_temps,
        sector_temps=np.asarray(sector_temps, dtype=np.int64),
    )


#: Fault provenance of the v6 fixture: one crashed worker survived via
#: a pool rebuild (wall_s pinned for determinism).
V6_FAULTS = (
    FaultEvent(kind="worker-crash", where="collector", shard=1,
               attempt=0, wall_s=0.0,
               detail="process pool broke (worker died)"),
    FaultEvent(kind="pool-rebuild", where="collector",
               detail="respawning 2 workers (consecutive failure 1)"),
)


def _heatmap(with_shards, with_faults=False):
    shards = (
        (
            ShardInfo(shard=0, lo=0, hi=2, programs=2, records=8,
                      dropped=0, wall_s=0.0),
            ShardInfo(shard=1, lo=2, hi=4, programs=2, records=8,
                      dropped=0, wall_s=0.0),
        )
        if with_shards
        else ()
    )
    return Heatmap(
        kernel="golden_kernel",
        grid=(4,),
        sampler="full",
        regions=(
            _region("x", "hbm", _X_WORD_TEMPS, _X_SECTOR_TEMPS),
            _region("acc", "vmem_scratch", _ACC_WORD_TEMPS,
                    _ACC_SECTOR_TEMPS),
        ),
        n_records=16,
        dropped=0,
        shards=shards,
        faults=V6_FAULTS if with_faults else (),
    )


def _rewrite_manifest(path, version, keep_tuning):
    """Strip the freshly written manifest down to ``version``'s shape."""
    mpath = path / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["version"] = version
    manifest["created"] = 0.0  # determinism: fixtures carry no wallclock
    if not keep_tuning:
        manifest.pop("tuning", None)
    if version < 6:
        manifest.pop("faults", None)  # v6-only recovery provenance
    if version < 5:
        manifest.pop("layers", None)  # v5-only attribution block
    for entry in manifest["kernels"]:
        if version < 4:
            entry.pop("scratch_words", None)  # v4+ metric
        if version < 2:
            entry["heatmap"].pop("shards", None)
        if version < 6:
            entry["heatmap"].pop("faults", None)
    mpath.write_text(json.dumps(manifest, indent=2) + "\n")


def write_fixtures(dest):
    """Write artifact-v1 … artifact-v6 under ``dest``; returns the paths."""
    dest = Path(dest)
    out = []
    for version in (1, 2, 3, 4, 5, 6):
        pk = ProfiledKernel(
            name="golden",
            variant="v00",
            heatmap=_heatmap(with_shards=version >= 2,
                             with_faults=version >= 6),
            reports=(),  # loaders recompute derived views from arrays
            actions=(),
            wall_s=0.0,
            region_map=(("x", "xT"),),
        )
        path = dest / f"artifact-v{version}"
        write_iteration(
            path,
            [pk],
            label=f"golden-v{version}",
            note="format-compat fixture",
            tuning=V3_TUNING if version >= 3 else None,
            layers=V5_LAYERS if version >= 5 else None,
        )
        _rewrite_manifest(path, version, keep_tuning=version >= 3)
        out.append(path)
    return out


if __name__ == "__main__":
    for p in write_fixtures(FIXTURES):
        print(f"wrote {p}", file=sys.stderr)
