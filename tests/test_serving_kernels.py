"""Serving-shaped kernel families: ragged flash + paged KV-cache attention.

Numerics vs the pure-jnp oracles (interpret mode), registry/trace/lint
plumbing for all eight variants, the dense-vs-dynamic transfer ladders
(the optimized rung must be strictly cheaper — that delta is what lets
``cuthermo tune`` accept it), and one closed tuner loop on the
``ragged_flash`` family with v3 provenance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels as K
from repro import kernels as kreg
from repro.core.lint import lint_ref
from repro.core.session import profile_kernel

RF = K.ragged_flash
PA = K.paged_attn


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.key(key), shape, dtype)


# ---------------------------------------------------------------------------
# numerics vs references
# ---------------------------------------------------------------------------


def test_ragged_decode_matches_reference():
    b, h, s, d = 4, 4, 128, 32
    q = _rand(0, (b, h, d))
    k = _rand(1, (b, s, d))
    v = _rand(2, (b, s, d))
    ctx = RF.ragged_context(b, s)
    starts = jnp.asarray(ctx["starts"])
    ends = jnp.asarray(ctx["ends"])
    got = RF.ragged_decode_attention(q, k, v, starts, ends, bkv=32)
    want = RF.ragged_decode_reference(q, k, v, starts, ends)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-4)


def test_ragged_decode_block_size_invariance():
    # the online-softmax accumulation must not depend on the KV tiling
    b, h, s, d = 2, 4, 128, 32
    q, k, v = _rand(0, (b, h, d)), _rand(1, (b, s, d)), _rand(2, (b, s, d))
    starts = jnp.asarray([0, 16], jnp.int32)
    ends = jnp.asarray([100, 128], jnp.int32)
    a = RF.ragged_decode_attention(q, k, v, starts, ends, bkv=32)
    bb = RF.ragged_decode_attention(q, k, v, starts, ends, bkv=64)
    np.testing.assert_allclose(a, bb, atol=2e-5, rtol=2e-4)


def test_paged_decode_matches_reference():
    b, h, d = 4, 4, 32
    pages, slots, page = 16, 4, 32
    q = _rand(0, (b, h, d))
    k_pages = _rand(1, (1, pages, page, d))
    v_pages = _rand(2, (1, pages, page, d))
    ctx = PA.paged_context(b, pages, slots, page)
    tables = jnp.asarray(ctx["block_tables"])
    lens = jnp.asarray(ctx["context_lens"])
    got = PA.paged_decode_attention(q, k_pages, v_pages, tables, lens)
    want = PA.paged_decode_reference(q, k_pages, v_pages, tables, lens)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-4)


def test_paged_decode_table_permutation_invariance():
    # physically relocating pages (and renaming them in the table) must
    # not change the attention output — the defining paged-cache property
    b, h, d = 2, 4, 32
    pages, slots, page = 8, 2, 32
    q = _rand(0, (b, h, d))
    k_pages = _rand(1, (1, pages, page, d))
    v_pages = _rand(2, (1, pages, page, d))
    tables = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
    lens = jnp.asarray([48, 64], jnp.int32)
    base = PA.paged_decode_attention(q, k_pages, v_pages, tables, lens)
    perm = np.asarray([5, 3, 7, 0, 2, 6, 1, 4])
    k2 = k_pages[:, perm]
    v2 = v_pages[:, perm]
    inv = np.argsort(perm)
    tables2 = jnp.asarray(inv[np.asarray(tables)], jnp.int32)
    moved = PA.paged_decode_attention(q, k2, v2, tables2, lens)
    np.testing.assert_allclose(base, moved, atol=2e-5, rtol=2e-4)


# ---------------------------------------------------------------------------
# registry + trace + lint plumbing
# ---------------------------------------------------------------------------

SERVING_REFS = (
    "ragged_flash:decode", "ragged_flash:decode-ragged",
    "ragged_flash:prefill", "ragged_flash:prefill-ragged",
    "paged_attn:decode", "paged_attn:decode-paged",
    "paged_attn:prefill", "paged_attn:prefill-paged",
)


def test_serving_families_are_registered():
    names = kreg.names()
    assert "ragged_flash" in names and "paged_attn" in names
    for family in ("ragged_flash", "paged_attn"):
        entry = kreg.get(family)
        assert [v.role for v in entry.variants] == [
            "baseline", "optimized", "baseline", "optimized"
        ]
        # the ladder proposes only the optimized rungs
        ladder = [v.name for _pos, v in entry.ladder(0)]
        assert all("-" in n for n in ladder), ladder


@pytest.mark.parametrize("ref", SERVING_REFS)
def test_serving_specs_build_and_trace(ref):
    spec, ctx = kreg.build(ref)
    assert spec.source == ref
    assert ctx is not None  # every serving variant carries its context
    pk = profile_kernel(spec, None, ctx, name=ref)
    assert pk.transactions > 0
    # the scalar-prefetch operands are present in the traced map
    regions = {r.region.name for r in pk.heatmap.regions}
    assert {"starts", "ends"} <= regions or {
        "block_tables", "context_lens"
    } <= regions


@pytest.mark.parametrize("ref", SERVING_REFS)
def test_serving_specs_lint_without_nonaffine(ref):
    # static variants must be fully affine; dynamic rungs must be
    # 'dynamic' (modeled), never 'nonaffine' (model failure) — the
    # lint pre-screen in `cuthermo tune` depends on this
    rep = lint_ref(ref)
    statuses = {ov.status for ov in rep.operands}
    assert "nonaffine" not in statuses, (ref, statuses)
    if ref.endswith(("-ragged", "-paged")):
        assert "dynamic" in statuses, (ref, statuses)
    else:
        assert rep.static_transactions is not None


def test_dynamic_rungs_are_strictly_cheaper():
    # the serving trick's whole point: the data-dependent rung moves
    # strictly fewer tiles than its dense baseline on the seeded context
    expected = {
        ("ragged_flash:decode", "ragged_flash:decode-ragged"): (576, 154),
        ("ragged_flash:prefill", "ragged_flash:prefill-ragged"):
            (4224, 2522),
        ("paged_attn:decode", "paged_attn:decode-paged"): (640, 288),
        ("paged_attn:prefill", "paged_attn:prefill-paged"): (6400, 4944),
    }
    for (dense_ref, dyn_ref), (dense_tx, dyn_tx) in expected.items():
        spec, ctx = kreg.build(dense_ref)
        dense = profile_kernel(spec, None, ctx)
        spec, ctx = kreg.build(dyn_ref)
        dyn = profile_kernel(spec, None, ctx)
        # pinned absolute counts: a context/shape drift that silently
        # changes the modeled traffic fails here, not in the tuner
        assert dense.transactions == dense_tx, dense_ref
        assert dyn.transactions == dyn_tx, dyn_ref
        assert dyn.transactions < dense.transactions


def test_serving_traces_are_deterministic():
    # the seeded context must make repeated collections bit-identical
    # (the property the collection cache and check gates rely on)
    from repro.core.session import heatmaps_equal

    spec, ctx = kreg.build("ragged_flash:decode-ragged")
    a = profile_kernel(spec, None, ctx)
    b = profile_kernel(spec, None, ctx)
    assert heatmaps_equal(a.heatmap, b.heatmap)


def test_tune_accepts_the_ragged_rung(tmp_path):
    # close the loop on the serving family: the tuner must accept an
    # improvement and persist v3 provenance for it
    from repro.core.session import ProfileSession
    from repro.core.tuner import trajectories_from_session

    with ProfileSession(tmp_path / "sess") as sess:
        res = sess.tune("ragged_flash:decode", budget=2, use_generated=False)
    assert res.improved
    assert res.best.transactions < res.baseline.transactions
    (traj,) = trajectories_from_session(
        ProfileSession(tmp_path / "sess", create=False)
    )
    assert traj["kernel"] == "ragged_flash"
    accepted = [s for s in traj["steps"] if s["accepted"]]
    assert accepted and accepted[0]["candidate"]["label"].startswith("ladder:")
