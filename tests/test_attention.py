"""flash_xla (fwd + custom VJP), KV caches, MLA absorbed decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests degrade to skips
from hypothesis import given, settings, strategies as st

from repro.models.attention import (
    AttnConfig,
    MLAConfig,
    attention_ref,
    attn_apply,
    attn_defs,
    flash_xla,
    init_cache,
    init_mla_cache,
    mla_apply,
    mla_defs,
)
from repro.models.params import init_params


def _qkv(s=96, h=4, kv=2, d=16, b=2):
    q = jax.random.normal(jax.random.key(0), (b, s, h, d))
    k = jax.random.normal(jax.random.key(1), (b, s, kv, d))
    v = jax.random.normal(jax.random.key(2), (b, s, kv, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    return q, k, v, pos


@given(
    s=st.sampled_from([17, 64, 100]),
    chunk=st.sampled_from([16, 32, 512]),
    causal=st.booleans(),
    window=st.sampled_from([None, 13]),
)
@settings(max_examples=16, deadline=None)
def test_flash_vs_ref_sweep(s, chunk, causal, window):
    q, k, v, pos = _qkv(s=s)
    got = flash_xla(q, k, v, pos, None, causal, window, chunk)
    want = attention_ref(q, k, v, pos, causal=causal, window=window)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-4)


def test_flash_custom_vjp_matches_autodiff():
    q, k, v, pos = _qkv(s=64)

    def f_flash(q, k, v):
        return jnp.sum(flash_xla(q, k, v, pos, None, True, None, 16) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v, pos, causal=True) ** 2)

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-4)


def test_flash_kv_length_mask():
    q, k, v, pos = _qkv(s=64)
    got = flash_xla(q, k, v, pos, jnp.asarray(40), True, None, 16)
    want = attention_ref(q, k, v, pos, kv_length=jnp.asarray(40), causal=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-4)


def test_gqa_cache_decode_matches_full():
    cfg = AttnConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8, chunk=16)
    params = init_params(attn_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 12, 32))
    pos = jnp.broadcast_to(jnp.arange(12)[None], (2, 12))
    full, _ = attn_apply(params, x, pos, cfg)
    cache = init_cache(2, 16, 2, 8, jnp.float32)
    y, cache = attn_apply(params, x[:, :6], pos[:, :6], cfg, cache)
    np.testing.assert_allclose(y, full[:, :6], atol=1e-5, rtol=1e-4)
    for t in range(6, 12):
        y, cache = attn_apply(params, x[:, t : t + 1], pos[:, t : t + 1], cfg, cache)
    np.testing.assert_allclose(y[:, 0], full[:, -1], atol=1e-5, rtol=1e-4)


def test_sliding_window_cache_decode():
    cfg = AttnConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                     sliding_window=4, chunk=8)
    params = init_params(attn_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 10, 32))
    pos = jnp.broadcast_to(jnp.arange(10)[None], (1, 10))
    full, _ = attn_apply(params, x, pos, cfg)
    cache = init_cache(1, 16, 2, 8, jnp.float32)
    y, cache = attn_apply(params, x[:, :9], pos[:, :9], cfg, cache)
    y, cache = attn_apply(params, x[:, 9:10], pos[:, 9:10], cfg, cache)
    np.testing.assert_allclose(y[:, 0], full[:, -1], atol=1e-5, rtol=1e-4)


def test_mla_decode_absorbed_matches_expanded():
    """The absorbed decode path must equal prefill-style expanded attention."""
    cfg = MLAConfig(d_model=32, n_heads=2, q_lora_rank=16, kv_lora_rank=16,
                    qk_nope_head_dim=8, qk_rope_head_dim=4, v_head_dim=8, chunk=8)
    params = init_params(mla_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 9, 32))
    pos = jnp.broadcast_to(jnp.arange(9)[None], (2, 9))
    full, _ = mla_apply(params, x, pos, cfg)
    cache = init_mla_cache(2, 16, cfg, jnp.float32)
    y, cache = mla_apply(params, x[:, :8], pos[:, :8], cfg, cache)
    np.testing.assert_allclose(y, full[:, :8], atol=1e-5, rtol=1e-4)
    # decode one token through the absorbed path
    y, cache = mla_apply(params, x[:, 8:9], pos[:, 8:9], cfg, cache)
    np.testing.assert_allclose(y[:, 0], full[:, 8], atol=1e-4, rtol=1e-3)


def test_mla_grads_flow():
    cfg = MLAConfig(d_model=32, n_heads=2, q_lora_rank=16, kv_lora_rank=16,
                    qk_nope_head_dim=8, qk_rope_head_dim=4, v_head_dim=8)
    params = init_params(mla_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 8, 32))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (1, 8))

    def loss(p):
        y, _ = mla_apply(p, x, pos, cfg)
        return jnp.sum(y**2)

    g = jax.grad(loss)(params)
    gn = sum(float(jnp.sum(v**2)) for v in jax.tree.leaves(g))
    assert gn > 0 and np.isfinite(gn)
