"""The deterministic fault-injection harness (the injection side).

Pins ``FaultPlan`` parsing/determinism/directive sequencing, the
disk-state injections (cache corruption -> quarantine, torn artifact
writes -> ``ProfileSession.recover()``), and the crash-safe session
commit protocol they exercise.  Recovery behavior under *live* injected
pool faults is pinned in ``tests/test_resilience.py``.
"""

import pytest

from repro.core.cache import CollectionCache
from repro.core.collector import analyze, sourced_spec
from repro.core.faultinject import (
    FaultInjectError,
    FaultPlan,
    InjectedKill,
    WriteKillPoint,
    apply_worker_directive,
    corrupt_cache_entry,
)
from repro.core.session import (
    JOURNAL_NAME,
    ProfileSession,
    heatmaps_equal,
    load_iteration,
    profile_kernel,
)
from repro.core.trace import GridSampler


# -- FaultPlan parsing -------------------------------------------------------


def test_parse_bare_seed_and_keys():
    assert FaultPlan.parse("7") == FaultPlan(seed=7)
    plan = FaultPlan.parse("seed=3, crashes=0, timeouts=1, "
                           "hang=5.5, watchdog=0.4")
    assert plan == FaultPlan(seed=3, crashes=0, timeouts=1,
                             hang_s=5.5, watchdog_s=0.4)
    assert "seed=3" in plan.describe() and "crashes=0" in plan.describe()


@pytest.mark.parametrize("bad", [
    "", "bogus=1", "seed", "seed=x", "crashes=2", "timeouts=-1",
])
def test_parse_rejects_malformed_specs(bad):
    with pytest.raises(FaultInjectError):
        FaultPlan.parse(bad)


def test_plan_policy_tightens_watchdog_only():
    from repro.core.resilience import ResiliencePolicy

    base = ResiliencePolicy(attempts=5, shard_timeout_s=300.0)
    tight = FaultPlan(watchdog_s=0.8).policy(base)
    assert tight.shard_timeout_s == 0.8
    assert tight.attempts == 5  # everything else inherits


# -- directive sequencing ----------------------------------------------------


def test_victim_shard_deterministic_and_in_range():
    plan = FaultPlan(seed=7)
    v = plan.victim_shard("gemm-v01", 4)
    assert v == plan.victim_shard("gemm-v01", 4)
    assert 0 <= v < 4
    # different seeds move the victim eventually (pure in seed+kernel)
    assert len({
        FaultPlan(seed=s).victim_shard("gemm-v01", 4) for s in range(16)
    }) > 1


def test_directive_sequencing_crash_then_hang():
    plan = FaultPlan(seed=7, crashes=1, timeouts=1, hang_s=9.0)
    victim = plan.victim_shard("k", 2)
    other = 1 - victim
    assert plan.directive("k", 2, victim, 0) == {"kind": "crash"}
    assert plan.directive("k", 2, victim, 1) == {
        "kind": "hang", "sleep_s": 9.0,
    }
    assert plan.directive("k", 2, victim, 2) is None
    for attempt in range(3):
        assert plan.directive("k", 2, other, attempt) is None
    # with the crash disabled, the hang moves up to the first delivery
    hang_only = FaultPlan(seed=7, crashes=0, timeouts=1)
    assert plan.victim_shard("k", 2) == hang_only.victim_shard("k", 2)
    assert hang_only.directive("k", 2, victim, 0)["kind"] == "hang"
    assert hang_only.directive("k", 2, victim, 1) is None


def test_apply_worker_directive_noop_hang_and_unknown():
    apply_worker_directive(None)  # no directive: no effect
    apply_worker_directive({"kind": "hang", "sleep_s": 0.0})
    with pytest.raises(FaultInjectError, match="unknown worker directive"):
        apply_worker_directive({"kind": "meltdown"})


# -- cache corruption -> quarantine ------------------------------------------


def _heatmap():
    spec = sourced_spec("repro.kernels.gemm:gemm_v00_spec", 128, 128, 128)
    return analyze(spec, sampler=GridSampler(None))


@pytest.mark.parametrize("mode", ["truncate", "garbage", "meta"])
def test_corrupt_entry_is_quarantined_not_fatal(tmp_path, mode):
    cache = CollectionCache(tmp_path / "cache")
    hm = _heatmap()
    cache.put("deadbeef01", hm)
    assert heatmaps_equal(cache.get("deadbeef01"), hm)

    corrupt_cache_entry(cache, "deadbeef01", mode=mode)
    with pytest.warns(RuntimeWarning, match="quarantine"):
        assert cache.get("deadbeef01") is None  # a miss, never an error
    assert cache.stats.corrupt == 1
    qdir = tmp_path / "cache" / "quarantine"
    assert qdir.is_dir() and any(qdir.iterdir())
    npz_path, _ = cache._entry_paths("deadbeef01")
    assert not npz_path.exists()  # evicted from the lookup path
    # the slot is reusable: a fresh store round-trips again
    cache.put("deadbeef01", hm)
    assert heatmaps_equal(cache.get("deadbeef01"), hm)


def test_corrupt_cache_entry_rejects_unknown_mode(tmp_path):
    cache = CollectionCache(tmp_path / "cache")
    cache.put("deadbeef01", _heatmap())
    with pytest.raises(FaultInjectError, match="corruption mode"):
        corrupt_cache_entry(cache, "deadbeef01", mode="cosmic-rays")


# -- torn artifact writes -> recover() ---------------------------------------


@pytest.fixture(scope="module")
def kernels():
    a = profile_kernel(
        sourced_spec("repro.kernels.gemm:gemm_v01_spec", 128, 128, 128),
        GridSampler(None),
    )
    b = profile_kernel(
        sourced_spec("repro.kernels.gemm:gemm_v00_spec", 128, 128, 128),
        GridSampler(None),
    )
    return [a, b]


def test_injected_kill_is_base_exception():
    # ordinary `except Exception` cleanup must not absorb the kill
    assert issubclass(InjectedKill, BaseException)
    assert not issubclass(InjectedKill, Exception)
    with pytest.raises(FaultInjectError):
        WriteKillPoint(kill_at="eventually")


def test_kill_before_manifest_quarantines_torn_iteration(tmp_path, kernels):
    sess = ProfileSession(tmp_path / "s")
    with pytest.raises(InjectedKill):
        with WriteKillPoint(after_files=1):
            sess.add_iteration(kernels, label="torn")
    d = tmp_path / "s" / "iter0"
    assert (d / JOURNAL_NAME).exists()
    assert not (d / "manifest.json").exists()

    events = sess.recover()
    assert [e.kind for e in events] == ["torn-iteration"]
    assert not d.exists()
    assert (tmp_path / "s" / "quarantine" / "iter0").is_dir()
    assert sess.iteration_names() == []
    # the slot is reusable after quarantine
    it = sess.add_iteration(kernels, label="retry")
    assert it.path.name == "iter0"
    assert heatmaps_equal(
        load_iteration(it.path).kernels[0].heatmap, kernels[0].heatmap
    )


def test_kill_with_manifest_staged_recovers_to_complete(tmp_path, kernels):
    """The fsync'd-but-not-renamed manifest state: recover() finishes
    the rename instead of discarding a fully durable iteration."""
    sess = ProfileSession(tmp_path / "s")
    with pytest.raises(InjectedKill):
        with WriteKillPoint(after_files=2, kill_at="staged"):
            sess.add_iteration(kernels, label="staged")
    d = tmp_path / "s" / "iter0"
    assert (d / "manifest.json.tmp").exists()
    assert not (d / "manifest.json").exists()

    events = sess.recover()
    assert [e.kind for e in events] == ["torn-iteration"]
    it = sess.iteration(0)
    assert it.label == "staged"
    assert heatmaps_equal(it.kernels[0].heatmap, kernels[0].heatmap)
    assert not (d / JOURNAL_NAME).exists()


def test_kill_after_manifest_commit_only_drops_journal(tmp_path, kernels):
    sess = ProfileSession(tmp_path / "s")
    with pytest.raises(InjectedKill):
        with WriteKillPoint(after_files=3):
            sess.add_iteration(kernels, label="late")
    d = tmp_path / "s" / "iter0"
    assert (d / "manifest.json").exists() and (d / JOURNAL_NAME).exists()

    sess.recover()
    assert sess.iteration(0).label == "late"
    assert not (d / JOURNAL_NAME).exists()


def test_recover_on_clean_session_is_a_noop(tmp_path, kernels):
    sess = ProfileSession(tmp_path / "s")
    sess.add_iteration(kernels, label="clean")
    assert sess.recover() == []
    assert sess.iteration(0).label == "clean"
