"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests degrade to skips
from hypothesis import given, settings, strategies as st

import repro.kernels as K
from repro.kernels import ref as R


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.key(key), shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# -- gemm ----------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mnk", [(32, 64, 32), (64, 64, 64), (128, 128, 64)])
def test_gemm_variants(dtype, mnk):
    m, n, k = mnk
    a, b = _rand(0, (m, k), dtype), _rand(1, (k, n), dtype)
    want = R.gemm_ref(a, b).astype(jnp.float32)
    tol = TOL[dtype] * k
    got = K.gemm.gemm_v00(a, b).astype(jnp.float32)
    np.testing.assert_allclose(got, want, atol=tol, rtol=tol)
    got = K.gemm.gemm_v01(a, b, bm=8).astype(jnp.float32)
    np.testing.assert_allclose(got, want, atol=tol, rtol=tol)
    got = K.gemm.gemm_v02(a, b, bm=32, bn=32, bk=32).astype(jnp.float32)
    np.testing.assert_allclose(got, want, atol=tol, rtol=tol)


# -- flash attention -------------------------------------------------------------


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("s,d,bq,bkv", [(128, 32, 64, 64), (256, 64, 128, 64)])
def test_flash_kernel(causal, s, d, bq, bkv):
    q = _rand(0, (4, s, d), jnp.float32)
    k = _rand(1, (4, s, d), jnp.float32)
    v = _rand(2, (4, s, d), jnp.float32)
    got = K.flash.flash_attention(q, k, v, causal=causal, bq=bq, bkv=bkv)
    want = R.flash_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-4)


def test_flash_kernel_bf16():
    q = _rand(0, (2, 128, 32), jnp.bfloat16)
    k = _rand(1, (2, 128, 32), jnp.bfloat16)
    v = _rand(2, (2, 128, 32), jnp.bfloat16)
    got = K.flash.flash_attention(q, k, v, causal=True, bq=64, bkv=64)
    want = R.flash_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), atol=3e-2, rtol=3e-2
    )


# -- ssd ------------------------------------------------------------------------


@pytest.mark.parametrize("l,p,n", [(16, 8, 4), (32, 16, 8), (64, 64, 16)])
def test_ssd_chunk_kernel(l, p, n):
    bh, c = 3, 4
    x = _rand(0, (bh, c, l, p), jnp.float32)
    a = -jnp.abs(_rand(1, (bh, c, l), jnp.float32)) * 0.4
    bm = _rand(2, (bh, c, l, n), jnp.float32)
    cm = _rand(3, (bh, c, l, n), jnp.float32)
    y, s = K.ssd.ssd_chunk(x, a, bm, cm)
    y2, s2 = R.ssd_chunk_ref(x, a, bm, cm)
    np.testing.assert_allclose(y, y2, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s, s2, atol=1e-4, rtol=1e-4)


# -- spmv -------------------------------------------------------------------------


@given(
    r=st.sampled_from([8, 32, 64]),
    k=st.sampled_from([4, 16, 33]),
)
@settings(max_examples=10, deadline=None)
def test_spmv_sweep(r, k):
    vals = _rand(0, (r, k), jnp.float32)
    xg = _rand(1, (r, k), jnp.float32)
    got = K.spmv.spmv_ell(vals, xg, br=8)
    np.testing.assert_allclose(got, R.spmv_ref(vals, xg), atol=1e-5, rtol=1e-4)


def test_spmv_csr_end_to_end(rng):
    """ELL kernel vs a scipy-style CSR oracle on a random sparse matrix."""
    n, nnz_per_row = 64, 6
    row_offsets = np.arange(0, (n + 1) * nnz_per_row, nnz_per_row).astype(np.int32)
    col_indices = rng.integers(0, n, size=n * nnz_per_row).astype(np.int32)
    values = rng.normal(size=n * nnz_per_row).astype(np.float32)
    x = rng.normal(size=n).astype(np.float32)
    idx, val = K.spmv.csr_to_ell(row_offsets, col_indices, values, n)
    xg = x[idx]
    got = K.spmv.spmv_ell(jnp.asarray(val), jnp.asarray(xg), br=8)
    want = R.spmv_csr_ref(row_offsets, col_indices, values, x)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


# -- ttm ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_scratch", [False, True])
@pytest.mark.parametrize("f,nf,r", [(16, 8, 32), (32, 4, 64)])
def test_ttm(use_scratch, f, nf, r):
    vals = _rand(0, (f, nf), jnp.float32)
    ur = _rand(1, (f, nf, r), jnp.float32)
    got = K.ttm.ttm(vals, ur, use_scratch=use_scratch)
    np.testing.assert_allclose(got, R.ttm_ref(vals, ur), atol=1e-5, rtol=1e-4)


# -- gramschm ---------------------------------------------------------------------


@pytest.mark.parametrize("k", [0, 3, 31])
def test_gramschm_k3(k):
    q = _rand(0, (64, 32), jnp.float32)
    a = _rand(1, (64, 256), jnp.float32)
    want = R.gramschm_k3_ref(q, a, k)
    got_naive = K.gramschm.gramschm_k3_naive(q, a, k)
    got_opt = K.gramschm.gramschm_k3_opt(q.T, a, k)
    np.testing.assert_allclose(got_naive, want, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(got_opt, want, atol=1e-4, rtol=1e-4)


# -- histogram ---------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["naive", "opt", "opt2"])
def test_histogram(variant):
    cells = jax.random.randint(jax.random.key(0), (4096,), 0, 64)
    fn = {"naive": K.histogram.hist_naive, "opt": K.histogram.hist_opt,
          "opt2": K.histogram.hist_opt2}[variant]
    got = fn(cells, 64)
    np.testing.assert_allclose(got, R.hist_ref(cells, 64), atol=0, rtol=0)


# -- gmm -----------------------------------------------------------------------------


@pytest.mark.parametrize("groups", [[100, 28, 0, 130], [64, 64, 64, 64], [0, 0, 5, 1]])
def test_gmm_vs_plan(groups):
    gs = np.asarray(groups)
    row_map, tile_ids, mp = K.gmm.plan_groups(gs, bm=32)
    x = _rand(0, (mp, 64), jnp.float32)
    w = _rand(1, (len(gs), 64, 48), jnp.float32)
    got = K.gmm.gmm(x, w, jnp.asarray(tile_ids), bm=32)
    want = K.gmm.gmm_ref(x, w, tile_ids, bm=32)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)


def test_gmm_matches_ragged_dot():
    gs = np.asarray([32, 64, 32])
    row_map, tile_ids, mp = K.gmm.plan_groups(gs, bm=32)
    assert mp == 128  # already tile multiples
    x = _rand(0, (128, 32), jnp.float32)
    w = _rand(1, (3, 32, 16), jnp.float32)
    got = K.gmm.gmm(x, w, jnp.asarray(tile_ids), bm=32)
    want = R.gmm_ragged_ref(x, w, jnp.asarray(gs, np.int32))
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)
