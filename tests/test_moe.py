"""MoE: ragged/capacity dispatch vs dense oracle, drops, aux loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import (
    MoEConfig,
    moe_apply,
    moe_apply_capacity,
    moe_apply_ragged,
    moe_defs,
    moe_ref,
)
from repro.models.params import init_params


def _setup(**kw):
    cfg = MoEConfig(d_model=16, d_ff=32, n_experts=4, top_k=2,
                    n_shared_experts=1, **kw)
    params = init_params(moe_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 12, 16))
    return cfg, params, x


def test_ragged_matches_dense_oracle():
    cfg, params, x = _setup()
    y, aux = moe_apply_ragged(params, x, cfg)
    y2, aux2 = moe_ref(params, x, cfg)
    np.testing.assert_allclose(y, y2, atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(aux, aux2, atol=1e-6, rtol=1e-5)


def test_capacity_high_cap_matches_oracle():
    cfg, params, x = _setup(capacity_factor=8.0, moe_impl="capacity")
    y, _ = moe_apply_capacity(params, x, cfg)
    y2, _ = moe_ref(params, x, cfg)
    np.testing.assert_allclose(y, y2, atol=1e-5, rtol=1e-4)


def test_capacity_drops_tokens_when_tight():
    cfg, params, x = _setup(capacity_factor=0.1, moe_impl="capacity")
    y_tight, _ = moe_apply_capacity(params, x, cfg)
    y_full, _ = moe_ref(params, x, cfg)
    # with cap this tight some tokens must differ (drops), but none NaN
    assert np.isfinite(np.asarray(y_tight)).all()
    assert float(jnp.abs(y_tight - y_full).max()) > 1e-6


def test_top1_and_no_shared():
    cfg = MoEConfig(d_model=16, d_ff=32, n_experts=4, top_k=1)
    params = init_params(moe_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 8, 16))
    y, aux = moe_apply_ragged(params, x, cfg)
    y2, _ = moe_ref(params, x, cfg)
    np.testing.assert_allclose(y, y2, atol=1e-5, rtol=1e-4)


def test_aux_loss_positive_and_bounded():
    cfg, params, x = _setup()
    _, aux = moe_apply(params, x, cfg)
    assert 0.0 <= float(aux) < 1.0


def test_moe_grads_flow_through_dispatch():
    cfg, params, x = _setup()

    def loss(p):
        y, aux = moe_apply_ragged(p, x, cfg)
        return jnp.sum(y**2) + aux

    g = jax.grad(loss)(params)
    # every expert weight that received tokens must have nonzero grad
    gw = np.asarray(jnp.abs(g["w_gate"]).sum(axis=(1, 2)))
    assert (gw > 0).sum() >= 2  # at least half the experts hit
    assert all(np.isfinite(np.asarray(v)).all() for v in jax.tree.leaves(g))


def test_ep_falls_back_without_mesh():
    """moe_impl='ep' on a single device (no active rules) must still work."""
    cfg, params, x = _setup(moe_impl="ep", capacity_factor=8.0)
    y, _ = moe_apply(params, x, cfg)
    y2, _ = moe_ref(params, x, cfg)
    np.testing.assert_allclose(y, y2, atol=1e-5, rtol=1e-4)
