"""Tile geometry properties (word/sector mapping)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests degrade to skips
from hypothesis import given, settings, strategies as st

from repro.core.tiles import LANES, TileGeometry, block_to_2d, sublanes_for


def test_sublanes_by_itemsize():
    assert sublanes_for(4) == 8
    assert sublanes_for(2) == 16
    assert sublanes_for(1) == 32
    assert sublanes_for(8) == 4
    with pytest.raises(ValueError):
        sublanes_for(3)


@given(
    rows=st.integers(1, 64),
    cols=st.integers(1, 512),
    itemsize=st.sampled_from([1, 2, 4, 8]),
)
@settings(max_examples=50, deadline=None)
def test_tag_roundtrip(rows, cols, itemsize):
    g = TileGeometry(shape=(rows, cols), itemsize=itemsize)
    for r in range(0, rows, max(1, rows // 5)):
        for c in range(0, cols, max(1, cols // 5)):
            tag = g.sector_tag(r, c)
            r0, c0 = g.tag_to_coords(tag)
            assert r0 <= r < r0 + g.sublanes
            assert c0 <= c < c0 + LANES
            assert 0 <= tag < g.n_sectors


@given(
    rows=st.integers(1, 48),
    cols=st.integers(1, 300),
    itemsize=st.sampled_from([2, 4]),
)
@settings(max_examples=30, deadline=None)
def test_full_slice_touches_every_word_once(rows, cols, itemsize):
    g = TileGeometry(shape=(rows, cols), itemsize=itemsize)
    touches = list(g.slice_to_touches(0, rows, 0, cols))
    # every (row, lane-tile) appears exactly once
    assert len(touches) == rows * g.lane_tiles
    assert len(set(touches)) == len(touches)


def test_slice_clipping():
    g = TileGeometry(shape=(16, 256), itemsize=4)
    assert list(g.slice_to_touches(-5, 0, 0, 10)) == []
    assert list(g.slice_to_touches(0, 1, 300, 400)) == []
    t = list(g.slice_to_touches(14, 100, 0, 128))
    assert len(t) == 2  # rows 14, 15 only


def test_1d_run_walks_sublane_rows():
    g = TileGeometry(shape=(1025,), itemsize=4)
    # 1024 int32 elements = 8 lane-rows = exactly 1 tile, aligned
    t = list(g.run_to_touches(0, 1024))
    assert len(t) == 8
    assert len({tag for tag, _ in t}) == 1
    # shifted by 1 element -> straddles into a 9th word / 2nd tile
    t2 = list(g.run_to_touches(1, 1025))
    assert len(t2) == 9
    assert len({tag for tag, _ in t2}) == 2


def test_alignment_check():
    g = TileGeometry(shape=(32, 256), itemsize=4)
    assert g.is_aligned_slice(0, 8, 0, 128)
    assert not g.is_aligned_slice(1, 9, 0, 128)
    assert not g.is_aligned_slice(0, 8, 64, 192)
    assert g.is_aligned_slice(24, 32, 128, 256)


def test_block_to_2d_contiguous():
    # 3-D operand (4, 8, 128), block (1, 8, 128): leading dim flattens
    r0, r1, c0, c1 = block_to_2d((4, 8, 128), (2, 0, 0), (1, 8, 128))
    assert (r0, r1, c0, c1) == (16, 24, 0, 128)
    with pytest.raises(ValueError):
        block_to_2d((4, 8, 128), (0, 0, 0), (2, 4, 128))  # non-contiguous
