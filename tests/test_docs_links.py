"""Docs integrity: every relative link in README + docs/*.md resolves."""

import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

from check_docs_links import check_file, default_files  # noqa: E402


def test_docs_surface_is_nonempty():
    files = default_files(REPO)
    names = {f.name for f in files}
    # the documented tree: README + the five core docs must exist
    assert "README.md" in names
    for doc in ("architecture.md", "cli.md", "file-format.md",
                "patterns.md", "tuning.md", "tutorial.md"):
        assert doc in names, f"docs/{doc} missing from the docs surface"


def test_every_relative_link_resolves():
    failures = []
    for f in default_files(REPO):
        for lineno, target in check_file(f):
            failures.append(f"{f.relative_to(REPO)}:{lineno}: {target}")
    assert not failures, "broken relative links:\n" + "\n".join(failures)


def test_checker_flags_a_broken_link(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("see [missing](does-not-exist.md) and [ok](bad.md)\n")
    breaks = check_file(bad)
    assert breaks == [(1, "does-not-exist.md")]


def test_checker_skips_external_and_fenced(tmp_path):
    doc = tmp_path / "doc.md"
    doc.write_text(
        "[web](https://example.com) [anchor](#section)\n"
        "```console\n[fake](inside-fence.md)\n```\n"
    )
    assert check_file(doc) == []


def test_checker_cli_exit_codes(tmp_path):
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs_links.py")],
        capture_output=True, text=True, timeout=60,
    )
    assert proc.returncode == 0, proc.stderr
    bad = tmp_path / "bad.md"
    bad.write_text("[x](nope.md)\n")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs_links.py"),
         str(bad)],
        capture_output=True, text=True, timeout=60,
    )
    assert proc.returncode == 1
    assert "nope.md" in proc.stderr
