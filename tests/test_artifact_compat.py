"""Artifact back-compat pinned by committed v1–v6 golden fixtures.

The fixtures under ``tests/fixtures/artifact-v*`` are files an OLD
writer could have produced (see ``tests/fixtures/generate.py``).  These
tests pin the load paths against them, so a change that breaks reading
historical artifacts fails here even if every code-rewrite round-trip
test still passes.
"""

import importlib.util
import json
import shutil
from pathlib import Path

import numpy as np
import pytest

from repro.core.session import (
    ARTIFACT_VERSION,
    SUPPORTED_VERSIONS,
    SessionError,
    heatmaps_equal,
    load_iteration,
)

FIXTURES = Path(__file__).parent / "fixtures"


def _load_generator():
    spec = importlib.util.spec_from_file_location(
        "fixture_generator", FIXTURES / "generate.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_every_supported_version_has_a_fixture():
    # the current version is exercised by the live writer; every OLD
    # version must be pinned by a committed artifact
    assert SUPPORTED_VERSIONS == (1, 2, 3, 4, 5, 6)
    assert ARTIFACT_VERSION == 6
    for version in SUPPORTED_VERSIONS:
        assert (FIXTURES / f"artifact-v{version}" / "manifest.json").is_file()


@pytest.mark.parametrize("version", [1, 2, 3, 4, 5, 6])
def test_fixture_loads_with_pinned_contents(version):
    it = load_iteration(FIXTURES / f"artifact-v{version}")
    assert it.label == f"golden-v{version}"
    (pk,) = it.kernels
    assert pk.name == "golden" and pk.variant == "v00"
    # golden temperatures: the exact arrays the fixture was built from
    x = pk.heatmap.region("x")
    assert np.array_equal(x.tags_array, np.array([0, 8, 16]))
    assert np.array_equal(x.sector_temps_array, np.array([2, 3, 1]))
    assert np.array_equal(
        x.word_temps_matrix[0], np.array([2, 2, 2, 2, 2, 2, 2, 2])
    )
    acc = pk.heatmap.region("acc")
    assert acc.region.space == "vmem_scratch"
    # derived metrics recompute from the arrays on every version,
    # including the v4-era scratch metric the old manifests never stored
    assert pk.transactions == 6
    assert pk.scratch_words == 32
    # the persisted region-rename survives (diff alignment input)
    assert pk.region_map == (("x", "xT"),)


def test_v1_fixture_has_no_provenance():
    it = load_iteration(FIXTURES / "artifact-v1")
    assert it.tuning is None
    assert it.kernels[0].shards == ()


def test_v2_fixture_carries_shards_but_no_tuning():
    it = load_iteration(FIXTURES / "artifact-v2")
    assert it.tuning is None
    shards = it.kernels[0].shards
    assert [s.shard for s in shards] == [0, 1]
    assert [(s.lo, s.hi) for s in shards] == [(0, 2), (2, 4)]
    assert sum(s.records for s in shards) == 16


def test_v3_fixture_carries_tuning_provenance():
    it = load_iteration(FIXTURES / "artifact-v3")
    assert it.tuning is not None
    assert it.tuning["role"] == "candidate"
    assert it.tuning["accepted"] is True
    assert it.tuning["candidate"]["label"] == "ladder:v01"


@pytest.mark.parametrize("version", [1, 2, 3, 4])
def test_pre_v5_fixtures_have_no_layers(version):
    # loaders must surface layers=None for artifacts written before the
    # per-layer attribution block existed — never a fabricated table
    it = load_iteration(FIXTURES / f"artifact-v{version}")
    assert it.layers is None


@pytest.mark.parametrize("version", [1, 2, 3, 4, 5])
def test_pre_v6_fixtures_load_with_clean_fault_provenance(version):
    # loaders must surface empty fault provenance for artifacts written
    # before recovery events existed — absent, not an error
    it = load_iteration(FIXTURES / f"artifact-v{version}")
    assert it.faults == ()
    assert it.kernels[0].heatmap.faults == ()


def test_v6_fixture_carries_fault_provenance():
    it = load_iteration(FIXTURES / "artifact-v6")
    # the heatmap rides structured FaultEvents ...
    events = it.kernels[0].heatmap.faults
    assert [e.kind for e in events] == ["worker-crash", "pool-rebuild"]
    assert events[0].shard == 1 and events[0].where == "collector"
    # ... and the manifest-only top-level block names the owning kernel
    assert [f["kind"] for f in it.faults] == ["worker-crash", "pool-rebuild"]
    assert all(f["kernel"] == "golden" for f in it.faults)
    # provenance is excluded from heat-map equality: the recovered map
    # IS the clean map (here, the v5 fixture's identical temperatures)
    clean = load_iteration(FIXTURES / "artifact-v5")
    assert heatmaps_equal(it.kernels[0].heatmap, clean.kernels[0].heatmap)


def test_v5_fixture_carries_layer_attribution():
    it = load_iteration(FIXTURES / "artifact-v5")
    assert it.layers is not None
    assert it.layers["model"] == "golden-tiny"
    table = it.layers["table"]
    assert [row["path"] for row in table] == ["layer0"]
    # the partition invariant: per-layer totals sum to the iteration total
    rollup = sum(row["transactions"] for row in table)
    assert rollup == sum(pk.transactions for pk in it.kernels) == 6
    # the HLO sweep block survives the round trip
    assert it.layers["hlo"]["cost"]["flops"] == 64.0
    assert it.layers["hlo"]["heat"]["collective_count"] == 0


def test_old_manifests_yield_history_points_without_scratch():
    # manifest-only history consumers must see scratch_words=None on
    # pre-v4 artifacts (skip the metric), never a fabricated zero
    from repro.core.session import _history_points_from_manifest

    for version in (1, 2, 3):
        manifest = json.loads(
            (FIXTURES / f"artifact-v{version}" / "manifest.json").read_text()
        )
        (pt,) = _history_points_from_manifest(manifest, f"artifact-v{version}")
        assert pt.kernel == "golden"
        assert pt.transactions == 6
        assert pt.scratch_words is None
    # v3 tuning provenance flows into the point
    assert pt.tuning_role == "candidate" and pt.tuning_accepted is True
    # v4+ manifests DO carry the stored metric
    for version in (4, 5):
        manifest = json.loads(
            (FIXTURES / f"artifact-v{version}" / "manifest.json").read_text()
        )
        (pt,) = _history_points_from_manifest(manifest, f"artifact-v{version}")
        assert pt.scratch_words == 32


def test_unknown_version_still_fails(tmp_path):
    target = tmp_path / "artifact"
    shutil.copytree(FIXTURES / "artifact-v1", target)
    mpath = target / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["version"] = 99
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(SessionError):
        load_iteration(target)


def test_fixtures_match_generator(tmp_path):
    """The committed fixtures are exactly what the generator writes.

    Guards both directions: editing the generator without regenerating,
    and hand-editing a fixture without updating the generator.
    """
    gen = _load_generator()
    gen.write_fixtures(tmp_path)
    for version in (1, 2, 3, 4, 5, 6):
        fresh = load_iteration(tmp_path / f"artifact-v{version}")
        committed = load_iteration(FIXTURES / f"artifact-v{version}")
        assert heatmaps_equal(fresh.kernels[0].heatmap,
                              committed.kernels[0].heatmap)
        assert fresh.label == committed.label
        assert fresh.tuning == committed.tuning
        assert fresh.layers == committed.layers
        assert fresh.kernels[0].shards == committed.kernels[0].shards
        # manifests agree byte-for-byte (created is pinned to 0.0)
        fresh_m = (tmp_path / f"artifact-v{version}" /
                   "manifest.json").read_text()
        committed_m = (FIXTURES / f"artifact-v{version}" /
                       "manifest.json").read_text()
        assert fresh_m == committed_m
