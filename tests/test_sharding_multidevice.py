"""Sharding rules + multi-device correctness (subprocess: 8 CPU devices).

The in-process tests cover the rules/spec machinery; the subprocess tests
prove REAL distributed execution: a sharded train step on an 8-device
mesh matching the single-device result, EP MoE all-to-all parity, and the
gpipe pipeline.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import mesh_axis_types
from repro.parallel.sharding import Rules, fixup_specs, make_rules, specs_from_logical

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_rules_lookup_and_dedup():
    rules = make_rules(data_axes=("pod", "data"), fsdp=True,
                       fsdp_axes=("pod", "data"))
    assert rules.get("batch") == ("pod", "data")
    assert rules.get("mlp") == ("model",)
    assert rules.get("layer") == ()
    # duplicate axis use across dims is deduped (first dim wins)
    spec = rules.spec(("embed", "mlp"))
    assert spec == P(("pod", "data"), "model")
    spec = rules.spec(("mlp", "mlp"))
    assert spec == P("model", None)


def test_extra_rules_take_precedence():
    rules = make_rules(extra=(("act_seq", ("model",)),))
    assert rules.get("act_seq") == ("model",)


def test_fixup_drops_nondivisible():
    mesh = jax.make_mesh((1,), ("model",), **mesh_axis_types(1))
    # fake a 16-wide model axis via a Mesh-like shim
    class FakeMesh:
        shape = {"model": 16, "data": 16}

    spec = P(None, "model", None)
    shaped = jax.ShapeDtypeStruct((64, 8, 128), np.float32)  # 8 % 16 != 0
    fixed = fixup_specs(spec, shaped, FakeMesh())
    assert fixed == P(None, None, None)
    shaped_ok = jax.ShapeDtypeStruct((64, 32, 128), np.float32)
    assert fixup_specs(spec, shaped_ok, FakeMesh()) == P(None, "model", None)


_SUBPROCESS_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import mesh_axis_types
"""


def _run_sub(body: str) -> dict:
    code = _SUBPROCESS_PRELUDE + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_train_step_matches_single_device():
    res = _run_sub("""
    from repro.models import ModelConfig, build_model
    from repro.optim import adamw, constant
    from repro.runtime import TrainConfig, build_train_step, init_state
    from repro.parallel.sharding import make_rules, specs_from_logical, fixup_specs

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                      dtype=jnp.float32)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (8, 16), 0, 128)
    labs = jnp.roll(toks, -1, 1)
    opt = adamw(constant(1e-2))
    tc = TrainConfig()

    # single-device reference
    st = init_state(params, opt, tc)
    step = build_train_step(lambda p,t,l: m.loss(p,t,l), opt, tc, donate=False)
    st1, met1 = step(st, toks, labs)

    # 8-device (2 data x 4 model) mesh
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         **mesh_axis_types(2))
    rules = make_rules()
    pspecs = fixup_specs(specs_from_logical(m.logical_specs(), rules), params, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    params_sh = jax.tree.map(jax.device_put, params, psh)
    st = init_state(params_sh, opt, tc)
    with mesh:
        st2, met2 = step(st, toks, labs)
    diff = max(float(jnp.abs(jax.device_get(a) - jax.device_get(b)).max())
               for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st2.params)))
    print(json.dumps({"loss1": float(met1["loss"]), "loss2": float(met2["loss"]),
                      "param_diff": diff}))
    """)
    assert abs(res["loss1"] - res["loss2"]) < 1e-4
    assert res["param_diff"] < 1e-3


def test_ep_moe_matches_reference_on_mesh():
    res = _run_sub("""
    from repro.models.moe import MoEConfig, moe_defs, moe_apply_ep, moe_ref
    from repro.models.params import init_params
    from repro.parallel.context import use_rules
    from repro.parallel.sharding import make_rules

    cfg = MoEConfig(d_model=16, d_ff=32, n_experts=8, top_k=2,
                    capacity_factor=8.0, moe_impl="ep")
    params = init_params(moe_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (4, 8, 16))
    y_ref, aux_ref = moe_ref(params, x, cfg)

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         **mesh_axis_types(2))
    rules = make_rules()
    with mesh, use_rules(rules):
        y, aux = jax.jit(lambda p, x: moe_apply_ep(p, x, cfg))(params, x)
    diff = float(jnp.abs(y - y_ref).max())
    print(json.dumps({"diff": diff, "aux": float(aux), "aux_ref": float(aux_ref)}))
    """)
    assert res["diff"] < 1e-4


def test_pipeline_parallel_matches_sequential():
    res = _run_sub("""
    from repro.parallel.pipeline import pipeline, bubble_fraction

    mesh = jax.make_mesh((4,), ("stage",),
                         **mesh_axis_types(1))
    n_stages, n_micro, dim = 4, 8, 16
    ws = jax.random.normal(jax.random.key(0), (n_stages, dim, dim)) * 0.3
    mbs = jax.random.normal(jax.random.key(1), (n_micro, 4, dim))

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    # sequential reference
    ref = mbs
    for i in range(n_stages):
        ref = jax.vmap(lambda x: stage_fn(ws[i], x))(ref)

    fn = pipeline(stage_fn, mesh, axis="stage")
    with mesh:
        out = jax.jit(fn)(ws, mbs)
    print(json.dumps({"diff": float(jnp.abs(out - ref).max()),
                      "bubble": bubble_fraction(n_stages, n_micro)}))
    """)
    assert res["diff"] < 1e-5
    assert 0 < res["bubble"] < 0.5
