"""Golden equivalence: the columnar engine vs the seed per-record engine.

Every case asserts *bit-identical* heat maps — region set, sector tags,
word temps, sector temps, contributor counts, record counts, plus the
derived transaction model (``sector_transactions``, ``waste_ratio``).
"""

import numpy as np
import pytest

from repro.core import analyze
from repro.core._reference import (
    ReferenceAnalyzer,
    analyze_reference,
    collect_reference,
    drain_dynamic_reference,
)
from repro.core.collector import (
    KernelSpec,
    OperandSpec,
    ScratchSpec,
    collect,
    drain_dynamic,
)
from repro.core.heatmap import Analyzer
from repro.core.trace import GridSampler


def assert_heatmaps_identical(got, want):
    assert got.kernel == want.kernel
    assert got.grid == want.grid
    assert got.n_records == want.n_records
    assert got.dropped == want.dropped
    assert got.region_names() == want.region_names()
    for g, w in zip(got.regions, want.regions):
        name = w.region.name
        assert g.region.name == name
        assert g.region.space == w.region.space
        assert g.n_programs == w.n_programs, name
        np.testing.assert_array_equal(
            g.tags_array, w.tags_array, err_msg=f"tags of {name}"
        )
        np.testing.assert_array_equal(
            g.word_temps_matrix, w.word_temps_matrix,
            err_msg=f"word temps of {name}",
        )
        np.testing.assert_array_equal(
            g.sector_temps_array, w.sector_temps_array,
            err_msg=f"sector temps of {name}",
        )
        # row views agree too (lazy materialization path)
        assert g.rows == w.rows, name
    assert got.sector_transactions() == want.sector_transactions()
    assert got.useful_word_transactions() == want.useful_word_transactions()
    assert got.waste_ratio() == want.waste_ratio()
    for name in got.region_names():
        assert got.waste_ratio(name) == want.waste_ratio(name), name
        assert (
            got.sector_transactions(name) == want.sector_transactions(name)
        ), name


SAMPLERS = [GridSampler((0,), window=8), GridSampler(None)]


@pytest.mark.parametrize("sampler", SAMPLERS, ids=["window8", "full"])
def test_gemm_equivalence(sampler):
    from repro.kernels.gemm import gemm_v00_spec, gemm_v01_spec, gemm_v02_spec

    for spec in (
        gemm_v00_spec(128, 128, 128),
        gemm_v01_spec(256, 256, 256),
        gemm_v02_spec(256, 256, 256, bm=64, bn=64, bk=64),
    ):
        assert_heatmaps_identical(
            analyze(spec, sampler), analyze_reference(spec, sampler)
        )


@pytest.mark.parametrize("sampler", SAMPLERS, ids=["window8", "full"])
def test_spmv_misaligned_origin_equivalence(sampler):
    from repro.kernels.spmv import spmv_csr_spec

    rng = np.random.default_rng(7)
    colidx = rng.integers(0, 2048, size=4096).astype(np.int32)
    spec = spmv_csr_spec(4096, 2048, block_rows=512)
    ctx = {"col_indices": colidx}
    assert_heatmaps_identical(
        analyze(spec, sampler, dynamic_context=ctx),
        analyze_reference(spec, sampler, dynamic_context=ctx),
    )


@pytest.mark.parametrize("sampler", SAMPLERS, ids=["window8", "full"])
def test_dynamic_gather_equivalence(sampler):
    from repro.kernels.histogram import hist_naive_spec

    rng = np.random.default_rng(3)
    cells = rng.integers(0, 512, size=8192).astype(np.int64)
    spec = hist_naive_spec(8192, 512, block=1024)
    ctx = {"cells": cells}
    assert_heatmaps_identical(
        analyze(spec, sampler, dynamic_context=ctx),
        analyze_reference(spec, sampler, dynamic_context=ctx),
    )


@pytest.mark.parametrize("sampler", SAMPLERS, ids=["window8", "full"])
def test_scratch_accumulator_equivalence(sampler):
    from repro.kernels.histogram import hist_opt2_spec
    from repro.kernels.ttm import cuszp_like_spec, ttm_scratch_spec

    for spec in (
        ttm_scratch_spec(256, 8, 32),
        hist_opt2_spec(16384, 512),
        cuszp_like_spec(32),
    ):
        assert_heatmaps_identical(
            analyze(spec, sampler), analyze_reference(spec, sampler)
        )


def test_misc_kernels_full_equivalence():
    """Sweep the remaining case-study specs at full trace."""
    from repro.kernels.gramschm import k3_naive_block_spec, k3_opt_spec
    from repro.kernels.spmv import spmv_zigzag_spec
    from repro.kernels.ttm import ttm_fused_spec

    rng = np.random.default_rng(11)
    colidx = rng.integers(0, 1024, size=2048).astype(np.int32)
    cases = [
        (k3_naive_block_spec(256, 256, 256, k=3), None),
        (k3_opt_spec(256, 256, 256, k=3), None),
        (ttm_fused_spec(128, 8, 32), None),
        (spmv_zigzag_spec(2048, 1024, block_rows=512),
         {"col_indices": colidx}),
    ]
    for spec, ctx in cases:
        assert_heatmaps_identical(
            analyze(spec, GridSampler(None), dynamic_context=ctx),
            analyze_reference(spec, GridSampler(None), dynamic_context=ctx),
        )


def test_drain_dynamic_equivalence():
    op = OperandSpec("x", (4096,), np.float32, (4096,), lambda i: (0,))
    rng = np.random.default_rng(5)
    trace = rng.integers(-64, 4096, size=(8, 96))
    for sampler in SAMPLERS:
        buf = drain_dynamic("k", (8,), op, trace, sampler)
        ref = drain_dynamic_reference("k", (8,), op, trace, sampler)
        an, ran = Analyzer("k", (8,), "s"), ReferenceAnalyzer("k", (8,), "s")
        an.ingest(buf)
        ran.ingest(ref)
        assert_heatmaps_identical(an.flush(), ran.flush())
        # record views agree up to object identity
        got = sorted(
            (r.program_id, r.touches) for r in buf.records
        )
        want = sorted((r.program_id, r.touches) for r in ref.records)
        assert got == want


def test_drain_dynamic_valid_mask_equivalence():
    op = OperandSpec("x", (1024, 256), np.float32, (8, 256), lambda i: (i, 0))
    rng = np.random.default_rng(9)
    trace = rng.integers(0, 1024 * 256, size=(4, 32))
    mask = rng.random((4, 32)) < 0.5
    buf = drain_dynamic("k", (4,), op, trace, GridSampler(None), mask)
    ref = drain_dynamic_reference("k", (4,), op, trace, GridSampler(None), mask)
    an, ran = Analyzer("k", (4,), "s"), ReferenceAnalyzer("k", (4,), "s")
    an.ingest(buf)
    ran.ingest(ref)
    assert_heatmaps_identical(an.flush(), ran.flush())


def test_compat_append_path_equivalence():
    """Record-at-a-time appends (the exact path) match the seed bitmasks,
    including duplicate touches and repeated program ids."""
    from repro.core._reference import ReferenceTraceBuffer
    from repro.core.tiles import TileGeometry
    from repro.core.trace import AccessRecord, RegionInfo, TraceBuffer

    geom = TileGeometry(shape=(64, 256), itemsize=4, name="A")
    recs = [
        ((0,), [(0, 0), (0, 0), (1, 3)]),  # duplicate touch
        ((1,), [(0, 0)]),
        ((0,), [(1, 3), (2, 7)]),  # repeated pid, overlapping touch
        ((2,), []),  # zero-touch record still counts as a contributor
    ]
    buf, ref = TraceBuffer(), ReferenceTraceBuffer()
    for b in (buf, ref):
        b.register_region(RegionInfo("A", geom))
        for pid, touches in recs:
            b.append(
                AccessRecord(array="A", site="k/A", space="hbm", kind="load",
                             program_id=pid, touches=tuple(touches))
            )
    an, ran = Analyzer("k", (4,), "s"), ReferenceAnalyzer("k", (4,), "s")
    an.ingest(buf)
    ran.ingest(ref)
    assert_heatmaps_identical(an.flush(), ran.flush())


def test_compress_region_matches_compress_rows():
    from repro.core.heatmap import compress_region, compress_rows
    from repro.kernels.gemm import gemm_v00_spec
    from repro.kernels.spmv import spmv_csr_spec

    rng = np.random.default_rng(2)
    colidx = rng.integers(0, 1024, size=2048).astype(np.int32)
    heatmaps = [
        analyze(gemm_v00_spec(512, 512, 512), GridSampler((0,), window=32)),
        analyze(spmv_csr_spec(2048, 1024, block_rows=512), GridSampler(None),
                dynamic_context={"col_indices": colidx}),
    ]
    for hm in heatmaps:
        for rh in hm.regions:
            assert compress_region(rh) == compress_rows(rh.rows)


def test_mixed_buffer_ingest_equivalence():
    """Two collect() buffers (overlapping pid windows) ingested into one
    Analyzer must still dedupe contributors exactly (cross-group path)."""
    from repro.kernels.gemm import gemm_v01_spec

    spec = gemm_v01_spec(256, 256, 256)
    buf1, _ = collect(spec, GridSampler((0,), window=8))
    buf2, _ = collect(spec, GridSampler((0,), window=16))  # superset window
    an = Analyzer(spec.name, spec.grid, "mixed")
    an.ingest(buf1)
    an.ingest(buf2)

    ref1, _ = collect_reference(spec, GridSampler((0,), window=8))
    ref2, _ = collect_reference(spec, GridSampler((0,), window=16))
    ran = ReferenceAnalyzer(spec.name, spec.grid, "mixed")
    ran.ingest(ref1)
    ran.ingest(ref2)
    assert_heatmaps_identical(an.flush(), ran.flush())
