"""Golden equivalence: the columnar engine vs the seed per-record engine.

Every case asserts *bit-identical* heat maps — region set, sector tags,
word temps, sector temps, contributor counts, record counts, plus the
derived transaction model (``sector_transactions``, ``waste_ratio``).
"""

import numpy as np
import pytest

from repro.core import analyze
from repro.core._reference import (
    ReferenceAnalyzer,
    analyze_reference,
    collect_reference,
    drain_dynamic_reference,
)
from repro.core.collector import (
    KernelSpec,
    OperandSpec,
    ScratchSpec,
    collect,
    drain_dynamic,
)
from repro.core.heatmap import Analyzer
from repro.core.trace import GridSampler


def assert_heatmaps_identical(got, want):
    assert got.kernel == want.kernel
    assert got.grid == want.grid
    assert got.n_records == want.n_records
    assert got.dropped == want.dropped
    assert got.region_names() == want.region_names()
    for g, w in zip(got.regions, want.regions):
        name = w.region.name
        assert g.region.name == name
        assert g.region.space == w.region.space
        assert g.n_programs == w.n_programs, name
        np.testing.assert_array_equal(
            g.tags_array, w.tags_array, err_msg=f"tags of {name}"
        )
        np.testing.assert_array_equal(
            g.word_temps_matrix, w.word_temps_matrix,
            err_msg=f"word temps of {name}",
        )
        np.testing.assert_array_equal(
            g.sector_temps_array, w.sector_temps_array,
            err_msg=f"sector temps of {name}",
        )
        # row views agree too (lazy materialization path)
        assert g.rows == w.rows, name
    assert got.sector_transactions() == want.sector_transactions()
    assert got.useful_word_transactions() == want.useful_word_transactions()
    assert got.waste_ratio() == want.waste_ratio()
    for name in got.region_names():
        assert got.waste_ratio(name) == want.waste_ratio(name), name
        assert (
            got.sector_transactions(name) == want.sector_transactions(name)
        ), name


SAMPLERS = [GridSampler((0,), window=8), GridSampler(None)]


@pytest.mark.parametrize("sampler", SAMPLERS, ids=["window8", "full"])
def test_gemm_equivalence(sampler):
    from repro.kernels.gemm import gemm_v00_spec, gemm_v01_spec, gemm_v02_spec

    for spec in (
        gemm_v00_spec(128, 128, 128),
        gemm_v01_spec(256, 256, 256),
        gemm_v02_spec(256, 256, 256, bm=64, bn=64, bk=64),
    ):
        assert_heatmaps_identical(
            analyze(spec, sampler), analyze_reference(spec, sampler)
        )


@pytest.mark.parametrize("sampler", SAMPLERS, ids=["window8", "full"])
def test_spmv_misaligned_origin_equivalence(sampler):
    from repro.kernels.spmv import spmv_csr_spec

    rng = np.random.default_rng(7)
    colidx = rng.integers(0, 2048, size=4096).astype(np.int32)
    spec = spmv_csr_spec(4096, 2048, block_rows=512)
    ctx = {"col_indices": colidx}
    assert_heatmaps_identical(
        analyze(spec, sampler, dynamic_context=ctx),
        analyze_reference(spec, sampler, dynamic_context=ctx),
    )


@pytest.mark.parametrize("sampler", SAMPLERS, ids=["window8", "full"])
def test_dynamic_gather_equivalence(sampler):
    from repro.kernels.histogram import hist_naive_spec

    rng = np.random.default_rng(3)
    cells = rng.integers(0, 512, size=8192).astype(np.int64)
    spec = hist_naive_spec(8192, 512, block=1024)
    ctx = {"cells": cells}
    assert_heatmaps_identical(
        analyze(spec, sampler, dynamic_context=ctx),
        analyze_reference(spec, sampler, dynamic_context=ctx),
    )


@pytest.mark.parametrize("sampler", SAMPLERS, ids=["window8", "full"])
def test_scratch_accumulator_equivalence(sampler):
    from repro.kernels.histogram import hist_opt2_spec
    from repro.kernels.ttm import cuszp_like_spec, ttm_scratch_spec

    for spec in (
        ttm_scratch_spec(256, 8, 32),
        hist_opt2_spec(16384, 512),
        cuszp_like_spec(32),
    ):
        assert_heatmaps_identical(
            analyze(spec, sampler), analyze_reference(spec, sampler)
        )


def test_misc_kernels_full_equivalence():
    """Sweep the remaining case-study specs at full trace."""
    from repro.kernels.gramschm import k3_naive_block_spec, k3_opt_spec
    from repro.kernels.spmv import spmv_zigzag_spec
    from repro.kernels.ttm import ttm_fused_spec

    rng = np.random.default_rng(11)
    colidx = rng.integers(0, 1024, size=2048).astype(np.int32)
    cases = [
        (k3_naive_block_spec(256, 256, 256, k=3), None),
        (k3_opt_spec(256, 256, 256, k=3), None),
        (ttm_fused_spec(128, 8, 32), None),
        (spmv_zigzag_spec(2048, 1024, block_rows=512),
         {"col_indices": colidx}),
    ]
    for spec, ctx in cases:
        assert_heatmaps_identical(
            analyze(spec, GridSampler(None), dynamic_context=ctx),
            analyze_reference(spec, GridSampler(None), dynamic_context=ctx),
        )


def test_drain_dynamic_equivalence():
    op = OperandSpec("x", (4096,), np.float32, (4096,), lambda i: (0,))
    rng = np.random.default_rng(5)
    trace = rng.integers(-64, 4096, size=(8, 96))
    for sampler in SAMPLERS:
        buf = drain_dynamic("k", (8,), op, trace, sampler)
        ref = drain_dynamic_reference("k", (8,), op, trace, sampler)
        an, ran = Analyzer("k", (8,), "s"), ReferenceAnalyzer("k", (8,), "s")
        an.ingest(buf)
        ran.ingest(ref)
        assert_heatmaps_identical(an.flush(), ran.flush())
        # record views agree up to object identity
        got = sorted(
            (r.program_id, r.touches) for r in buf.records
        )
        want = sorted((r.program_id, r.touches) for r in ref.records)
        assert got == want


def test_drain_dynamic_valid_mask_equivalence():
    op = OperandSpec("x", (1024, 256), np.float32, (8, 256), lambda i: (i, 0))
    rng = np.random.default_rng(9)
    trace = rng.integers(0, 1024 * 256, size=(4, 32))
    mask = rng.random((4, 32)) < 0.5
    buf = drain_dynamic("k", (4,), op, trace, GridSampler(None), mask)
    ref = drain_dynamic_reference("k", (4,), op, trace, GridSampler(None), mask)
    an, ran = Analyzer("k", (4,), "s"), ReferenceAnalyzer("k", (4,), "s")
    an.ingest(buf)
    ran.ingest(ref)
    assert_heatmaps_identical(an.flush(), ran.flush())


def test_compat_append_path_equivalence():
    """Record-at-a-time appends (the exact path) match the seed bitmasks,
    including duplicate touches and repeated program ids."""
    from repro.core._reference import ReferenceTraceBuffer
    from repro.core.tiles import TileGeometry
    from repro.core.trace import AccessRecord, RegionInfo, TraceBuffer

    geom = TileGeometry(shape=(64, 256), itemsize=4, name="A")
    recs = [
        ((0,), [(0, 0), (0, 0), (1, 3)]),  # duplicate touch
        ((1,), [(0, 0)]),
        ((0,), [(1, 3), (2, 7)]),  # repeated pid, overlapping touch
        ((2,), []),  # zero-touch record still counts as a contributor
    ]
    buf, ref = TraceBuffer(), ReferenceTraceBuffer()
    for b in (buf, ref):
        b.register_region(RegionInfo("A", geom))
        for pid, touches in recs:
            b.append(
                AccessRecord(array="A", site="k/A", space="hbm", kind="load",
                             program_id=pid, touches=tuple(touches))
            )
    an, ran = Analyzer("k", (4,), "s"), ReferenceAnalyzer("k", (4,), "s")
    an.ingest(buf)
    ran.ingest(ref)
    assert_heatmaps_identical(an.flush(), ran.flush())


def test_compress_region_matches_compress_rows():
    from repro.core.heatmap import compress_region, compress_rows
    from repro.kernels.gemm import gemm_v00_spec
    from repro.kernels.spmv import spmv_csr_spec

    rng = np.random.default_rng(2)
    colidx = rng.integers(0, 1024, size=2048).astype(np.int32)
    heatmaps = [
        analyze(gemm_v00_spec(512, 512, 512), GridSampler((0,), window=32)),
        analyze(spmv_csr_spec(2048, 1024, block_rows=512), GridSampler(None),
                dynamic_context={"col_indices": colidx}),
    ]
    for hm in heatmaps:
        for rh in hm.regions:
            assert compress_region(rh) == compress_rows(rh.rows)


def test_mixed_buffer_ingest_equivalence():
    """Two collect() buffers (overlapping pid windows) ingested into one
    Analyzer must still dedupe contributors exactly (cross-group path)."""
    from repro.kernels.gemm import gemm_v01_spec

    spec = gemm_v01_spec(256, 256, 256)
    buf1, _ = collect(spec, GridSampler((0,), window=8))
    buf2, _ = collect(spec, GridSampler((0,), window=16))  # superset window
    an = Analyzer(spec.name, spec.grid, "mixed")
    an.ingest(buf1)
    an.ingest(buf2)

    ref1, _ = collect_reference(spec, GridSampler((0,), window=8))
    ref2, _ = collect_reference(spec, GridSampler((0,), window=16))
    ran = ReferenceAnalyzer(spec.name, spec.grid, "mixed")
    ran.ingest(ref1)
    ran.ingest(ref2)
    assert_heatmaps_identical(an.flush(), ran.flush())


# ---------------------------------------------------------------------------
# merge algebra: any partition of a trace into shards merges bit-identically
# ---------------------------------------------------------------------------


def _shard_cases():
    """Kernel cases exercising every collector path under sharding:
    static broadcast operands, once= single-program stores, scratch
    accumulators, and dynamic (Level-2) CSR operands."""
    from repro.kernels.gemm import gemm_v00_spec, gemm_v01_spec
    from repro.kernels.histogram import hist_naive_spec, hist_opt2_spec
    from repro.kernels.spmv import spmv_csr_spec
    from repro.kernels.ttm import ttm_scratch_spec

    rng = np.random.default_rng(17)
    return [
        (gemm_v00_spec(256, 256, 256), None),
        (gemm_v01_spec(256, 256, 256), None),
        (ttm_scratch_spec(256, 8, 32), None),
        (hist_opt2_spec(16384, 512), None),  # once= final store
        (hist_naive_spec(8192, 512, block=1024),
         {"cells": rng.integers(0, 512, size=8192).astype(np.int64)}),
        (spmv_csr_spec(4096, 2048, block_rows=512),
         {"col_indices": rng.integers(0, 2048, size=4096).astype(np.int32)}),
    ]


def _partition_merge(spec, ctx, bounds, sampler=None):
    """Collect each [lo, hi) shard, unify tokens, flush ONE analyzer."""
    from repro.core.collector import _unify_shard_groups, collect_shard

    sampler = sampler or GridSampler(None)
    results = [
        collect_shard(spec, sampler, ctx, lo, hi, i)
        for i, (lo, hi) in enumerate(bounds)
    ]
    bufs = [b for b, _ in results]
    _unify_shard_groups(bufs)
    an = Analyzer(spec.name, spec.grid, sampler.describe())
    for buf in bufs:
        an.ingest(buf)
    return an.flush()


def _heatmap_merge(spec, ctx, bounds, sampler=None):
    """Flush each shard with key state, fold through Heatmap.merge."""
    from repro.core.collector import collect_shard

    sampler = sampler or GridSampler(None)
    merged = None
    for i, (lo, hi) in enumerate(bounds):
        buf, _ = collect_shard(spec, sampler, ctx, lo, hi, i)
        an = Analyzer(spec.name, spec.grid, sampler.describe())
        an.ingest(buf)
        hm = an.flush(keep_keys=True)
        merged = hm if merged is None else merged.merge(hm)
    return merged


def _strip_keys(hm):
    """Key state is an internal carrier; compare the flushed arrays."""
    for rh in hm.regions:
        rh.key_state = None
    return hm


@pytest.mark.parametrize("n_shards", [2, 3, 5])
def test_partitioned_chunk_merge_bit_identical(n_shards):
    """Sharded chunk-level merge == serial single pass, every case."""
    from repro.core.collector import shard_bounds

    for spec, ctx in _shard_cases():
        serial = analyze(spec, GridSampler(None), dynamic_context=ctx)
        total = int(np.prod(spec.grid))
        sharded = _partition_merge(
            spec, ctx, shard_bounds(total, n_shards)
        )
        assert_heatmaps_identical(sharded, serial)


def test_partitioned_heatmap_merge_bit_identical():
    """Heatmap.merge over key-state shards == serial single pass."""
    from repro.core.collector import shard_bounds

    for spec, ctx in _shard_cases():
        serial = analyze(spec, GridSampler(None), dynamic_context=ctx)
        total = int(np.prod(spec.grid))
        merged = _heatmap_merge(spec, ctx, shard_bounds(total, 3))
        assert_heatmaps_identical(_strip_keys(merged), serial)


def test_uneven_partition_merge_bit_identical():
    """Degenerate partitions (empty and single-program shards) merge
    exactly too — the monoid has an identity."""
    from repro.kernels.gemm import gemm_v00_spec

    spec = gemm_v00_spec(128, 128, 128)
    serial = analyze(spec, GridSampler(None))
    bounds = [(0, 0), (0, 1), (1, 1), (1, 128)]
    assert_heatmaps_identical(_partition_merge(spec, None, bounds), serial)
    assert_heatmaps_identical(
        _strip_keys(_heatmap_merge(spec, None, bounds)), serial
    )


def test_overlapping_heatmap_merge_is_union_not_sum():
    """Merging OVERLAPPING shards must union contributors, not add
    temperatures — the defining property of the merge algebra."""
    from repro.kernels.gemm import gemm_v01_spec

    spec = gemm_v01_spec(256, 256, 256)
    # the same full grid twice: union == one pass, sum would double
    full = [(0, int(np.prod(spec.grid)))] * 2
    serial = analyze(spec, GridSampler(None))
    merged = _heatmap_merge(spec, None, full)
    assert merged.n_records == 2 * serial.n_records  # records DO add
    for name in serial.region_names():  # temperatures do NOT
        np.testing.assert_array_equal(
            merged.region(name).word_temps_matrix,
            serial.region(name).word_temps_matrix,
        )
        np.testing.assert_array_equal(
            merged.region(name).sector_temps_array,
            serial.region(name).sector_temps_array,
        )


def test_sharded_collector_inprocess_bit_identical():
    """The ShardedCollector fallback (no registry source) end to end."""
    from repro.core.collector import ShardedCollector

    for spec, ctx in _shard_cases():
        serial = analyze(spec, GridSampler(None), dynamic_context=ctx)
        with ShardedCollector(3) as sc:
            sharded = sc.analyze(spec, GridSampler(None), ctx)
        assert len(sharded.shards) == 3
        assert sum(s.programs for s in sharded.shards) == int(
            np.prod(spec.grid)
        )
        assert_heatmaps_identical(sharded, serial)


def test_collection_cache_hits_bit_identical(tmp_path):
    """GOLDEN: a cache hit — memory tier or a fresh process's disk tier —
    reproduces the freshly collected heat map exactly, for every shard
    case (operand walks, dynamic gathers, scratch accumulators)."""
    from repro.core.cache import CollectionCache, spec_content_hash

    cache = CollectionCache(tmp_path / "cache")
    for spec, ctx in _shard_cases():
        serial = analyze(spec, GridSampler(None), dynamic_context=ctx)
        key = spec_content_hash(spec, GridSampler(None), ctx)
        cache.put(key, serial)
        assert_heatmaps_identical(cache.get(key), serial)  # memory tier
        rebooted = CollectionCache(tmp_path / "cache")  # fresh process
        assert_heatmaps_identical(rebooted.get(key), serial)


try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # property tests degrade to the deterministic ones
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def _partitions(draw, total):
        """A random contiguous partition of range(total) into shards."""
        n_cuts = draw(st.integers(min_value=0, max_value=min(6, total)))
        cuts = sorted(
            draw(
                st.lists(
                    st.integers(min_value=0, max_value=total),
                    min_size=n_cuts,
                    max_size=n_cuts,
                )
            )
        )
        edges = [0] + cuts + [total]
        return list(zip(edges[:-1], edges[1:]))

    @given(data=st.data(), case=st.integers(min_value=0, max_value=5))
    @settings(max_examples=20, deadline=None)
    def test_any_partition_merges_bit_identically(data, case):
        """PROPERTY: for ANY contiguous partition of the sampled grid,
        both merge paths reproduce the single-pass heat map exactly."""
        spec, ctx = _shard_cases()[case]
        total = int(np.prod(spec.grid))
        bounds = data.draw(_partitions(total))
        serial = analyze(spec, GridSampler(None), dynamic_context=ctx)
        assert_heatmaps_identical(
            _partition_merge(spec, ctx, bounds), serial
        )
        assert_heatmaps_identical(
            _strip_keys(_heatmap_merge(spec, ctx, bounds)), serial
        )
