"""Dry-run machinery: cell registry, input specs, and one real compile
per mesh in a subprocess (512 placeholder devices)."""

import json
import os
import subprocess
import sys

import pytest

from repro.configs import SHAPES, all_cells, cells, skipped_cells

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_cell_registry_counts():
    runnable = all_cells()
    skips = skipped_cells()
    assert len(runnable) + len(skips) == 40  # 10 archs x 4 shapes
    assert len(skips) == 8  # long_500k on the 8 full-attention archs
    assert ("mamba2-2.7b", "long_500k") in runnable
    assert ("jamba-v0.1-52b", "long_500k") in runnable
    assert all(s == "long_500k" for _, s, _ in skips)


def test_shapes_match_assignment():
    assert SHAPES["train_4k"].seq_len == 4096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768 and SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].seq_len == 32768 and SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288 and SHAPES["long_500k"].global_batch == 1


@pytest.mark.parametrize("multi", [False, True], ids=["single16x16", "multi2x16x16"])
def test_one_cell_compiles_subprocess(multi):
    """Lower+compile a real full-size cell on the production mesh."""
    code = f"""
import json
from repro.launch.dryrun import run_cell
res = run_cell("granite-3-2b", "decode_32k", multi_pod={multi}, verbose=False)
print(json.dumps({{"ok": res["ok"], "chips": res["chips"],
                   "flops": res["cost"]["flops"],
                   "wire": res["collectives"]["total_wire_bytes_per_device"]}}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("JAX_PLATFORMS", None)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"]
    assert res["chips"] == (512 if multi else 256)
    assert res["flops"] > 0


def test_dryrun_artifacts_complete():
    """After the sweeps: every runnable cell has a recorded artifact."""
    art = os.path.join(REPO, "artifacts", "dryrun")
    if not os.path.isdir(art):
        pytest.skip("dry-run artifacts not generated yet")
    meshes = [d for d in os.listdir(art) if os.path.isdir(os.path.join(art, d))]
    assert "single_16x16" in meshes
    single = os.path.join(art, "single_16x16")
    have = {fn[:-5] for fn in os.listdir(single) if fn.endswith(".json")}
    want = {f"{a}__{s}" for a, s in all_cells()}
    assert want <= have, want - have
    # spot-check one artifact's schema
    with open(os.path.join(single, "granite-8b__train_4k.json")) as f:
        d = json.load(f)
    for key in ("roofline", "memory", "collectives", "bound", "model_flops"):
        assert key in d
