"""Regression gate: thresholds, verdicts, anomaly bands, exit codes."""

import json
import os

import pytest

from repro import cli
from repro.core.advisor import advise
from repro.core.check import (
    CHECK_SCHEMA_VERSION,
    Anomaly,
    CheckError,
    CheckThresholds,
    check_iterations,
    check_session_anomalies,
    detect_anomalies,
    merge_reports,
    pct_delta,
    robust_band,
)
from repro.core.collector import analyze
from repro.core.patterns import detect_all
from repro.core.session import (
    HistoryPoint,
    ProfiledKernel,
    ProfileSession,
    load_iteration,
    write_iteration,
)
from repro.core.trace import GridSampler
from repro.kernels.gemm import gemm_v00_spec, gemm_v01_spec

FULL = GridSampler(None)


def _profiled(name="gemm", variant="v00", spec_fn=gemm_v00_spec, n=128,
              with_reports=True):
    hm = analyze(spec_fn(n, n, n), sampler=FULL)
    return ProfiledKernel(
        name=name,
        variant=variant,
        heatmap=hm,
        reports=tuple(detect_all(hm)) if with_reports else (),
        actions=tuple(advise(hm)),
    )


@pytest.fixture(scope="module")
def naive():
    return _profiled("gemm", "v00", gemm_v00_spec)


@pytest.fixture(scope="module")
def tiled():
    return _profiled("gemm", "v01", gemm_v01_spec)


def _iteration(tmp_path, name, kernels, **kw):
    return load_iteration(
        write_iteration(tmp_path / name, kernels, label=name, **kw)
    )


# -- thresholds parsing -----------------------------------------------------


def test_thresholds_defaults_are_strict():
    t = CheckThresholds()
    assert t.max_transfer_pct == 0.0
    assert t.max_aggregate_pct == 0.0
    assert t.max_scratch_pct == 0.0
    assert t.fail_on_new_patterns and t.fail_on_missing
    assert t.allowed_patterns == ()


def test_thresholds_from_specs():
    t = CheckThresholds.from_specs(
        ["transfer-pct=5", "aggregate-pct=2.5", "scratch-pct=inf",
         "severity=0.1", "new-patterns=off", "missing=off",
         "allow-pattern=hot", "allow-pattern=strided",
         "allow-pattern=hot"]
    )
    assert t.max_transfer_pct == 5.0
    assert t.max_aggregate_pct == 2.5
    assert t.max_scratch_pct == float("inf")
    assert t.max_severity_increase == 0.1
    assert not t.fail_on_new_patterns and not t.fail_on_missing
    assert t.allowed_patterns == ("hot", "strided")  # deduped, ordered
    json.dumps(t.as_dict())  # JSON-ready


@pytest.mark.parametrize("spec", [
    "bogus=1",                # unknown key
    "transfer-pct",           # no '='
    "transfer-pct=abc",       # not a number
    "new-patterns=maybe",     # not on|off
    "allow-pattern=nope",     # unknown pattern class
])
def test_thresholds_bad_specs_raise(spec):
    with pytest.raises(CheckError):
        CheckThresholds.from_specs([spec])


def test_pct_delta_edges():
    assert pct_delta(100, 150) == 50.0
    assert pct_delta(100, 50) == -50.0
    assert pct_delta(0, 0) == 0.0
    assert pct_delta(0, 5) is None  # unbounded growth from zero


# -- baseline gate ----------------------------------------------------------


def test_check_identical_iterations_pass(tmp_path, tiled):
    base = _iteration(tmp_path, "base", [tiled])
    good = _iteration(tmp_path, "good", [tiled])
    report = check_iterations(base, good)
    assert report.passed and report.failures == ()
    (kc,) = report.kernels
    assert kc.status == "pass" and kc.verdict == "unchanged"
    assert report.aggregate.failures == ()
    assert "check passed" in report.summary()


def test_check_regression_fails_on_transfers_and_patterns(
    tmp_path, naive, tiled
):
    base = _iteration(tmp_path, "base", [tiled])
    bad = _iteration(tmp_path, "bad", [naive])
    report = check_iterations(base, bad)
    assert not report.passed
    (kc,) = report.kernels
    assert kc.status == "fail" and kc.verdict == "regressed"
    assert kc.transactions_after > kc.transactions_before
    assert any("false-sharing" in f for f in kc.failures)
    assert any("transfers" in f for f in kc.failures)
    # the aggregate budget is blown too
    assert report.aggregate.failures
    assert "FAILED" in report.summary()


def test_check_improvement_passes(tmp_path, naive, tiled):
    # less traffic + fixed patterns: strict gate, still green
    base = _iteration(tmp_path, "base", [naive])
    cand = _iteration(tmp_path, "cand", [tiled])
    report = check_iterations(base, cand)
    assert report.passed
    (kc,) = report.kernels
    assert kc.verdict == "improved"
    assert kc.fixed_patterns  # the false-sharing fix is recorded


def test_check_lenient_thresholds_absorb_regression(tmp_path, naive, tiled):
    base = _iteration(tmp_path, "base", [tiled])
    bad = _iteration(tmp_path, "bad", [naive])
    t = CheckThresholds.from_specs(
        ["transfer-pct=900", "aggregate-pct=900", "new-patterns=off"]
    )
    assert check_iterations(base, bad, thresholds=t).passed
    # allow-pattern exempts the class instead of switching the rule off
    t2 = CheckThresholds.from_specs(
        ["transfer-pct=900", "aggregate-pct=900",
         "allow-pattern=false-sharing"]
    )
    report = check_iterations(base, bad, thresholds=t2)
    assert report.passed and report.kernels[0].new_patterns == ()


def test_check_missing_and_added_kernels(tmp_path, naive, tiled):
    both = _profiled("other", "v01", gemm_v01_spec)
    base = _iteration(tmp_path, "base", [tiled, both])
    cand = _iteration(
        tmp_path, "cand",
        [tiled, _profiled("third", "v01", gemm_v01_spec)],
    )
    report = check_iterations(base, cand)
    by_name = {kc.kernel: kc for kc in report.kernels}
    assert by_name["other"].status == "missing"
    assert by_name["other"].failures  # strict default: missing fails
    assert by_name["third"].status == "added"
    assert by_name["third"].failures == ()  # informational only
    assert not report.passed
    lenient = CheckThresholds.from_specs(["missing=off"])
    assert check_iterations(base, cand, thresholds=lenient).passed


def test_check_disjoint_iterations_raise(tmp_path, tiled):
    base = _iteration(tmp_path, "base", [tiled])
    cand = _iteration(
        tmp_path, "cand", [_profiled("unrelated", "v01", gemm_v01_spec)]
    )
    with pytest.raises(CheckError):
        check_iterations(base, cand)


def test_check_scratch_gate(tmp_path):
    from pathlib import Path

    from repro import kernels as kreg
    from repro.core.session import Iteration

    def ttm(ref, name="ttm"):
        spec, ctx = kreg.build(ref)
        entry, variant = kreg.resolve(ref)
        hm = analyze(spec, sampler=entry.sampler(), dynamic_context=ctx)
        # reports stripped: isolate the scratch gate from pattern rules
        # (in-memory Iterations, since the disk loader recomputes them)
        return ProfiledKernel(name=name, variant=variant.name, heatmap=hm,
                              reports=(), actions=())

    base = Iteration(path=Path("base"), label="base", created=0.0,
                     kernels=(ttm("ttm:fused"),))
    cand = Iteration(path=Path("cand"), label="cand", created=0.0,
                     kernels=(ttm("ttm:scratch"),))
    report = check_iterations(base, cand)
    (kc,) = report.kernels
    assert kc.scratch_before == 0 and kc.scratch_after > 0
    assert kc.scratch_delta_pct is None  # growth from zero
    assert any("scratch words" in f for f in kc.failures)
    # the pattern rule independently flags the new scratch-abuse too
    assert ("Y_shr", "scratch-abuse") in kc.new_patterns
    # growth from zero blows any finite budget...
    # (new-patterns=off isolates the scratch gate from the pattern rule)
    t = CheckThresholds.from_specs(
        ["scratch-pct=1000000", "new-patterns=off"]
    )
    assert not check_iterations(base, cand, thresholds=t).passed
    # ...and only the explicit inf escape hatch disables the gate
    t = CheckThresholds.from_specs(["scratch-pct=inf", "new-patterns=off"])
    assert check_iterations(base, cand, thresholds=t).passed


def test_check_region_rename_alignment(tmp_path):
    from repro.kernels.gramschm import k3_naive_spec, k3_opt_spec

    def gs(spec_fn, variant):
        hm = analyze(spec_fn(512, 512, 512, k=3), sampler=FULL)
        return ProfiledKernel(name="gramschm", variant=variant, heatmap=hm,
                              reports=tuple(detect_all(hm)), actions=())

    base = _iteration(tmp_path, "base", [gs(k3_naive_spec, "naive")])
    cand = _iteration(tmp_path, "cand", [gs(k3_opt_spec, "opt")])
    report = check_iterations(
        base, cand, region_maps={"gramschm": {"q": "qT"}}
    )
    # with the rename aligned, q's strided fix is credited, and the one
    # honest trade-off (the transposed q runs hot) is surfaced by name
    (kc,) = report.kernels
    assert kc.verdict == "improved"
    assert ("q", "strided") in kc.fixed_patterns
    assert kc.new_patterns == (("q", "hot"),)
    assert report.failures == ("gramschm: new pattern: hot on q",)
    # exempting the traded-in class turns the improvement green
    t = CheckThresholds.from_specs(["allow-pattern=hot"])
    assert check_iterations(
        base, cand, thresholds=t, region_maps={"gramschm": {"q": "qT"}}
    ).passed
    # self-check under the rename map: the rename must be a no-op
    assert check_iterations(
        base, base, region_maps={"gramschm": {"q": "qT"}}
    ).passed


# -- report document --------------------------------------------------------


def test_report_json_schema(tmp_path, naive, tiled):
    base = _iteration(tmp_path, "base", [tiled])
    bad = _iteration(tmp_path, "bad", [naive])
    doc = check_iterations(base, bad).as_dict()
    json.dumps(doc)  # serializable end to end
    assert doc["format"] == "cuthermo-check"
    assert doc["schema_version"] == CHECK_SCHEMA_VERSION == 1
    assert doc["passed"] is False and doc["mode"] == "baseline"
    for key in ("candidate", "baseline", "thresholds", "kernels",
                "aggregate", "anomalies", "failures"):
        assert key in doc
    (kc,) = doc["kernels"]
    for key in ("kernel", "status", "verdict", "failures",
                "transactions_before", "transactions_after",
                "transactions_delta_pct", "scratch_before",
                "scratch_after", "new_patterns", "worsened_patterns"):
        assert key in kc
    assert doc["failures"]  # flat list mirrors the per-kernel ones


# -- anomaly bands ----------------------------------------------------------


def _pt(i, tx, patterns=(), scratch=0, accepted=None):
    return HistoryPoint(
        iteration=f"iter{i}", label=f"iter{i}", created=float(i),
        kernel="k", variant="v", transactions=tx, waste_ratio=1.0,
        patterns=tuple(patterns), scratch_words=scratch,
        tuning_accepted=accepted,
    )


def test_robust_band_is_deterministic_and_floored():
    values = [100.0, 101.0, 99.0, 100.0]
    assert robust_band(values) == robust_band(values)
    med, mad, lo, hi = robust_band(values, nmads=4.0, rel_floor=0.02)
    assert med == 100.0
    # MAD term vs relative floor: the band is never tighter than 2%
    assert hi - med >= 0.02 * med
    # zero-spread history still admits the floor's wiggle
    _, _, lo0, hi0 = robust_band([50.0, 50.0, 50.0])
    assert lo0 < 50.0 < hi0


def test_detect_anomalies_flags_spike_not_wiggle():
    stable = [_pt(i, 1000) for i in range(4)]
    flags, meta = detect_anomalies({"k": stable + [_pt(4, 5000)]})
    assert [a.metric for a in flags] == ["transactions"]
    a = flags[0]
    assert a.kernel == "k" and a.value == 5000.0 and a.iteration == "iter4"
    assert meta["kernels_scanned"] == 1
    # a within-floor wiggle does not flag
    flags2, _ = detect_anomalies({"k": stable + [_pt(4, 1010)]})
    assert flags2 == ()


def test_detect_anomalies_pattern_count_and_scratch():
    stable = [_pt(i, 1000, patterns=(("r", "hot"),)) for i in range(3)]
    latest = _pt(3, 1000, patterns=(("r", "hot"), ("r", "strided"),
                                    ("s", "hot")))
    flags, _ = detect_anomalies({"k": stable + [latest]})
    assert {a.metric for a in flags} == {"patterns"}
    # scratch growth flags on its own metric
    hist = [_pt(i, 1000, scratch=100) for i in range(3)]
    flags2, _ = detect_anomalies({"k": hist + [_pt(3, 1000, scratch=900)]})
    assert {a.metric for a in flags2} == {"scratch_words"}


def test_detect_anomalies_skips_short_and_unversioned_history():
    # fewer than min_history prior points: kernel skipped entirely
    flags, meta = detect_anomalies({"k": [_pt(0, 10), _pt(1, 9000)]})
    assert flags == () and meta["kernels_skipped"] == 1
    # pre-v4 artifacts (scratch None) skip the scratch metric only
    hist = [_pt(i, 1000, scratch=None) for i in range(3)]
    flags2, _ = detect_anomalies({"k": hist + [_pt(3, 1000, scratch=10**6)]})
    assert flags2 == ()


def test_anomaly_over_session_is_deterministic(tmp_path, naive, tiled):
    sess = ProfileSession(tmp_path / "sess")
    for _ in range(4):
        sess.add_iteration([tiled])
    sess.add_iteration([naive])
    r1 = check_session_anomalies(sess)
    r2 = check_session_anomalies(sess)
    assert r1.as_dict() == r2.as_dict()  # acceptance: deterministic
    assert not r1.passed
    assert {a.metric for a in r1.anomalies} == {"transactions", "patterns"}
    assert r1.mode == "anomaly"
    json.dumps(r1.as_dict())


def test_anomaly_excludes_tuner_rejected_iterations(tmp_path, naive, tiled):
    sess = ProfileSession(tmp_path / "sess")
    for _ in range(4):
        sess.add_iteration([tiled])
    # a candidate the tuner already rejected must not pollute the band
    sess.add_iteration(
        [naive],
        tuning={"family": "gemm", "step": 1, "role": "candidate",
                "accepted": False},
    )
    sess.add_iteration([tiled])
    assert check_session_anomalies(sess).passed
    # ...unless explicitly included (now the band sees the spike)
    history = sess.history(include_rejected=True)
    assert len(history["gemm"]) == 6
    assert len(sess.history(include_rejected=False)["gemm"]) == 5


def test_merge_reports_combines_modes(tmp_path, naive, tiled):
    from repro.core.check import CheckReport

    base = _iteration(tmp_path, "base", [tiled])
    good = _iteration(tmp_path, "good", [tiled])
    baseline_report = check_iterations(base, good)
    anomaly = Anomaly(kernel="gemm", metric="transactions", value=9.0,
                      median=1.0, mad=0.0, lo=0.9, hi=1.1, n_history=3)
    anomaly_report = CheckReport(mode="anomaly", candidate="s",
                                 anomalies=(anomaly,),
                                 anomaly_meta={"nmads": 4.0})
    merged = merge_reports(baseline_report, anomaly_report)
    assert merged.mode == "baseline+anomaly"
    assert not merged.passed  # the anomaly flag fails the merged gate
    assert merged.kernels == baseline_report.kernels


# -- CLI exit-code contract -------------------------------------------------


@pytest.fixture()
def gate_dirs(tmp_path, naive, tiled):
    write_iteration(tmp_path / "base", [tiled], label="base")
    write_iteration(tmp_path / "good", [tiled], label="good")
    write_iteration(tmp_path / "bad", [naive], label="bad")
    return tmp_path


def test_cli_check_pass_is_exit_0(gate_dirs, capsys):
    rc = cli.main(["check", str(gate_dirs / "good"),
                   "--baseline", str(gate_dirs / "base")])
    assert rc == 0
    assert "check passed" in capsys.readouterr().out


def test_cli_check_gate_failure_is_exit_1(gate_dirs, capsys):
    rc = cli.main(["check", str(gate_dirs / "bad"),
                   "--baseline", str(gate_dirs / "base")])
    assert rc == 1
    assert "FAILED" in capsys.readouterr().out


def test_cli_check_usage_and_load_errors_are_exit_2(gate_dirs, capsys):
    # nothing to gate against
    assert cli.main(["check", str(gate_dirs / "good")]) == 2
    # missing artifact
    assert cli.main(["check", str(gate_dirs / "nope"),
                     "--baseline", str(gate_dirs / "base")]) == 2
    # bad threshold spec
    assert cli.main(["check", str(gate_dirs / "good"),
                     "--baseline", str(gate_dirs / "base"),
                     "--threshold", "bogus=1"]) == 2
    # bad region map spec
    assert cli.main(["check", str(gate_dirs / "good"),
                     "--baseline", str(gate_dirs / "base"),
                     "--region-map", "nocolon"]) == 2
    # --anomaly on a non-session directory
    assert cli.main(["check", str(gate_dirs / "good"),
                     "--anomaly"]) == 2
    capsys.readouterr()


def test_cli_check_writes_json_and_sidecar(gate_dirs, capsys):
    out = gate_dirs / "check-report.json"
    rc = cli.main(["check", str(gate_dirs / "bad"),
                   "--baseline", str(gate_dirs / "base"),
                   "--json", str(out), "--quiet"])
    assert rc == 1
    capsys.readouterr()
    doc = json.loads(out.read_text())
    assert doc["schema_version"] == CHECK_SCHEMA_VERSION
    assert doc["passed"] is False
    # the sidecar lands next to the candidate artifact
    sidecar = json.loads((gate_dirs / "bad" / "check.json").read_text())
    assert sidecar == doc


def test_cli_check_json_stdout(gate_dirs, capsys):
    rc = cli.main(["check", str(gate_dirs / "good"),
                   "--baseline", str(gate_dirs / "base"), "--json", "-"])
    assert rc == 0
    captured = capsys.readouterr()
    doc = json.loads(captured.out)  # stdout is pure JSON
    assert doc["passed"] is True
    assert "check passed" in captured.err  # summary moved to stderr


def test_cli_check_anomaly_session_flow(tmp_path, naive, tiled, capsys):
    sess = ProfileSession(tmp_path / "sess")
    for _ in range(4):
        sess.add_iteration([tiled])
    sess.add_iteration([naive])
    rc = cli.main(["check", str(tmp_path / "sess"), "--anomaly"])
    assert rc == 1
    assert "anomal" in capsys.readouterr().out
    # combined mode: baseline gate + anomaly scan in one report
    write_iteration(tmp_path / "base", [tiled], label="base")
    rc = cli.main(["check", str(tmp_path / "sess"),
                   "--baseline", str(tmp_path / "base"),
                   "--anomaly", "--json", str(tmp_path / "c.json"),
                   "--quiet"])
    assert rc == 1
    capsys.readouterr()
    doc = json.loads((tmp_path / "c.json").read_text())
    assert doc["mode"] == "baseline+anomaly"
    assert doc["anomalies"]["flags"]
    # loosening the band silences the anomaly gate
    rc = cli.main(["check", str(tmp_path / "sess"), "--anomaly",
                   "--nmads", "4", "--min-history", "6", "--quiet"])
    assert rc == 0
    capsys.readouterr()


def test_cli_report_renders_check_verdict(gate_dirs, capsys, tmp_path):
    assert cli.main(["check", str(gate_dirs / "bad"),
                     "--baseline", str(gate_dirs / "base"),
                     "--quiet"]) == 1
    out = tmp_path / "bundle"
    assert cli.main(["report", str(gate_dirs / "bad"),
                     "--out", str(out)]) == 0
    capsys.readouterr()
    html = (out / "index.html").read_text()
    assert "regression check" in html and "FAILED" in html
    md = (out / "report.md").read_text()
    assert "regression check" in md
