"""Optimizers, schedules, clipping, quantized moments."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adamw,
    clip_by_global_norm,
    constant,
    cosine_warmup,
    global_norm,
    linear_warmup,
    lion,
)


def _rosenbrock_ish(params):
    x, y = params["x"], params["y"]
    return jnp.sum((1 - x) ** 2) + 10 * jnp.sum((y - x**2) ** 2)


@pytest.mark.parametrize("make_opt", [
    lambda: adamw(constant(3e-2)),
    lambda: adamw(constant(3e-2), state_dtype="bf16"),
    lambda: adamw(constant(3e-2), state_dtype="int8"),
    lambda: lion(constant(3e-3)),
])
def test_optimizer_minimizes(make_opt):
    opt = make_opt()
    params = {"x": jnp.zeros(4), "y": jnp.zeros(4)}
    state = opt.init(params)
    loss0 = float(_rosenbrock_ish(params))

    @jax.jit
    def step(params, state):
        loss, g = jax.value_and_grad(_rosenbrock_ish)(params)
        params, state = opt.update(g, state, params)
        return params, state, loss

    for _ in range(200):
        params, state, loss = step(params, state)
    assert float(loss) < 0.2 * loss0


def test_adamw_weight_decay_shrinks():
    opt = adamw(constant(1e-2), weight_decay=0.5)
    params = {"w": jnp.ones(8) * 10.0}
    state = opt.init(params)
    zeros = {"w": jnp.zeros(8)}
    for _ in range(10):
        params, state = opt.update(zeros, state, params)
    assert float(jnp.abs(params["w"]).max()) < 10.0


def test_clip_by_global_norm():
    g = {"a": jnp.ones(100) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) == pytest.approx(100.0, rel=1e-5)
    small = {"a": jnp.ones(4) * 0.01}
    unclipped, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(unclipped["a"], small["a"], rtol=1e-6)


def test_schedules():
    cos = cosine_warmup(1.0, 10, 100, floor=0.1)
    assert float(cos(jnp.asarray(0))) == 0.0
    assert float(cos(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(cos(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)
    lin = linear_warmup(2.0, 4)
    assert float(lin(jnp.asarray(2))) == pytest.approx(1.0)
    assert float(lin(jnp.asarray(8))) == pytest.approx(2.0)


def test_int8_state_memory_is_quarter():
    opt = adamw(constant(1e-3), state_dtype="int8")
    params = {"w": jnp.zeros((128, 128))}
    st = opt.init(params)
    assert st.m["w"].dtype == jnp.int8
    assert st.v["w"].dtype == jnp.int8
    assert st.mu is not None and st.nu is not None
