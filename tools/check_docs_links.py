"""Docs link checker: every relative markdown link must resolve.

Scans the repo's markdown surface (README.md + docs/*.md by default) for
inline links and images, and verifies that every *relative* target —
including the docs' cross-references to each other and links into the
source tree — exists on disk.  External (http/https/mailto) targets and
pure in-page anchors are skipped; a `path#anchor` target is checked for
the path part only.

Exit code 1 lists every broken link as ``file:line: target``; CI runs
this as the ``docs-links`` job, and ``tests/test_docs_links.py`` runs it
in the tier-1 suite.

Usage:
    python tools/check_docs_links.py            # repo default set
    python tools/check_docs_links.py FILE...    # explicit files
"""

from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import List, Tuple

REPO_ROOT = Path(__file__).resolve().parent.parent

#: Inline markdown links/images: [text](target) / ![alt](target).
#: Targets with spaces or nested parens are not used in this repo.
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")

#: Schemes that are not filesystem targets.
_EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def default_files(root: Path = REPO_ROOT) -> List[Path]:
    """The repo's linked markdown surface: README.md + the docs tree."""
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("*.md"))
    return [f for f in files if f.is_file()]


def check_file(path: Path) -> List[Tuple[int, str]]:
    """All broken relative links of one markdown file as (line, target)."""
    broken: List[Tuple[int, str]] = []
    inside_fence = False
    for lineno, line in enumerate(
        path.read_text(encoding="utf-8").splitlines(), start=1
    ):
        # fenced code blocks hold transcripts, not navigable links
        if line.lstrip().startswith("```"):
            inside_fence = not inside_fence
            continue
        if inside_fence:
            continue
        for target in _LINK_RE.findall(line):
            if target.startswith(_EXTERNAL):
                continue
            file_part = target.split("#", 1)[0]
            if not file_part:  # pure in-page anchor
                continue
            resolved = (path.parent / file_part).resolve()
            if not resolved.exists():
                broken.append((lineno, target))
    return broken


def _display(path: Path) -> str:
    """Repo-relative path when possible, absolute otherwise."""
    try:
        return str(path.resolve().relative_to(REPO_ROOT))
    except ValueError:
        return str(path)


def main(argv: List[str]) -> int:
    """Check every file (or the default set); print and count breaks."""
    files = [Path(a) for a in argv] if argv else default_files()
    failures: List[str] = []
    for f in files:
        for lineno, target in check_file(f):
            failures.append(f"{_display(f)}:{lineno}: {target}")
    if failures:
        print("broken relative links:", file=sys.stderr)
        for item in failures:
            print(f"  {item}", file=sys.stderr)
        return 1
    print(f"docs-links: {len(files)} files checked, all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
