"""Rebuild the committed CI baseline artifact (artifacts/ci-baseline).

The ``check-smoke`` CI job gates every PR by profiling the benchmark
kernels fresh and running ``cuthermo check --baseline`` against the
iteration this script writes.  Profiling is deterministic integer
arithmetic over seeded contexts, so a freshly profiled candidate
matches the committed baseline exactly — any drift IS the signal the
gate exists to catch.

Regenerate (only after a deliberate change to the profiler's modeled
counts or the benchmark kernels) with::

    PYTHONPATH=src python tools/make_ci_baseline.py

then commit the updated ``artifacts/ci-baseline``.  The baseline uses
each family's *optimized* rung (``gemm:v01``, ``gramschm:opt``, and the
model-derived ``model.transformer-tiny.mlp:v02``) under the registry's
default sampler — the same spec/sampler the CI job profiles — stored
under the plain family names the check aligns on.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro import kernels as kreg  # noqa: E402
from repro.core.session import profile_kernel, write_iteration  # noqa: E402

#: The baseline rungs: family name -> registry ref to profile.
BASELINE_REFS = {
    "gemm": "gemm:v01",
    "gramschm": "gramschm:opt",
    # a whole-model-derived family: the transformer-tiny FFN GEMM on its
    # blocked rung, synthesized by repro.models.registry.kernel_entry.
    # Stored under the full family name — that is the name `cuthermo
    # profile --kernel model.transformer-tiny.mlp:v02` aligns on.
    "model.transformer-tiny.mlp": "model.transformer-tiny.mlp:v02",
}

OUT = Path(__file__).resolve().parent.parent / "artifacts" / "ci-baseline"


def main() -> int:
    profiled = []
    for name, ref in BASELINE_REFS.items():
        entry, variant = kreg.resolve(ref)
        spec, ctx = kreg.build(ref)
        pk = profile_kernel(
            spec,
            entry.sampler(),
            ctx,
            name=name,
            variant=variant.name,
            region_map=entry.region_map,
        )
        profiled.append(pk)
        print(
            f"profiled {ref} as {name!r}: {pk.transactions} transfers, "
            f"{len(pk.reports)} patterns",
            file=sys.stderr,
        )
    write_iteration(
        OUT,
        profiled,
        label="ci-baseline",
        note="committed baseline for the check-smoke CI gate "
        "(tools/make_ci_baseline.py)",
    )
    print(f"wrote {OUT}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
