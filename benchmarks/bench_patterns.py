"""Table I reproduction: patterns detected per application/kernel.

Paper (Table I): GEMM v00 -> A hot/false-shared, B false-shared; SpMV ->
rowOffsets misaligned + x hot-random; PASTA -> Y_shr abused SMEM;
GRAMSCHM -> q strided; cuSZp -> exel_sum/base_idx abused SMEM; GPUMD ->
cell_count strided/false-shared.

This bench runs the Level-1/2 profiler over the TPU-native analogue of
each kernel and reports (kernel, data object, detected pattern) rows —
the direct analogue of the paper's table.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import analyze, detect_all
from repro.core.trace import GridSampler
from repro.kernels.gemm import gemm_v00_spec, gemm_v01_spec, gemm_v02_spec
from repro.kernels.gramschm import k3_naive_spec, k3_opt_spec
from repro.kernels.histogram import hist_naive_spec, hist_opt2_spec
from repro.kernels.spmv import spmv_csr_spec, spmv_zigzag_spec
from repro.kernels.ttm import cuszp_like_spec, ttm_fused_spec, ttm_scratch_spec

# paper-faithful expectations per (app, kernel, object)
EXPECTED: List[Tuple[str, str, str, set]] = [
    ("GEMM", "gemm_v00", "B", {"hot", "false-sharing"}),
    ("GEMM", "gemm_v00", "C", {"false-sharing"}),
    ("GEMM", "gemm_v01", "B", {"hot"}),
    ("SpMV", "spmv_csr", "rowOffsets_shift1", {"misalignment"}),
    ("SpMV", "spmv_csr", "x", {"hot", "hot-random"}),
    ("PASTA", "spt_TTMRankRBNnzKernelSM", "Y_shr", {"scratch-abuse"}),
    ("cuSZp", "cuszp_compress_like", "exel_sum", {"scratch-abuse"}),
    ("cuSZp", "cuszp_compress_like", "base_idx", {"scratch-abuse"}),
    ("GRAMSCHM", "gramschmidt_kernel3", "q", {"strided"}),
    ("GPUMD", "find_cell_counts", "cell_count", {"hot", "false-sharing", "strided"}),
]


def run() -> List[Tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    rows = []
    t0 = time.perf_counter()

    # GEMM
    hm00 = analyze(gemm_v00_spec(1024, 1024, 1024), GridSampler((0,), window=32))
    hm01 = analyze(gemm_v01_spec(1024, 1024, 1024), GridSampler((0,), window=32))
    hm02 = analyze(gemm_v02_spec(1024, 1024, 1024), GridSampler((0,), window=8))
    # SpMV: 36417x36417-ish matrix scale (paper footnote 2), zipf columns
    ncols = 36417
    colidx = np.minimum(
        rng.zipf(1.3, size=65536).astype(np.int64) * 37 % ncols, ncols - 1
    ).astype(np.int32)
    hm_spmv = analyze(
        spmv_csr_spec(65536, ncols), GridSampler((0,), window=32),
        dynamic_context={"col_indices": colidx},
    )
    hm_zig = analyze(
        spmv_zigzag_spec(65536, ncols), GridSampler((0,), window=32),
        dynamic_context={"col_indices": colidx},
    )
    # PASTA / cuSZp / GRAMSCHM / GPUMD
    hm_ttm = analyze(ttm_scratch_spec(512, 8, 32), GridSampler((0,), window=32))
    hm_ttm_f = analyze(ttm_fused_spec(512, 8, 32), GridSampler((0,), window=32))
    hm_cusz = analyze(cuszp_like_spec(64), GridSampler((0,), window=32))
    hm_gs = analyze(k3_naive_spec(512, 512, 512, k=3), GridSampler((0,), window=4))
    hm_gs_o = analyze(k3_opt_spec(512, 512, 512, k=3), GridSampler((0,), window=4))
    cells = rng.integers(0, 2048, size=65536).astype(np.int64)
    hm_gpumd = analyze(
        hist_naive_spec(65536, 2048), GridSampler((0,), window=32),
        dynamic_context={"cells": cells},
    )
    hm_gpumd_o = analyze(hist_opt2_spec(65536, 2048), GridSampler((0,), window=32))

    heatmaps = {
        "gemm_v00": hm00, "gemm_v01": hm01, "gemm_v02": hm02,
        "spmv_csr": hm_spmv, "spmv_zigzag": hm_zig,
        "spt_TTMRankRBNnzKernelSM": hm_ttm,
        "spt_TTMRankRBNnzKernel_reg": hm_ttm_f,
        "cuszp_compress_like": hm_cusz,
        "gramschmidt_kernel3": hm_gs, "gramschmidt_kernel3_opt": hm_gs_o,
        "find_cell_counts": hm_gpumd, "find_cell_counts_opt2": hm_gpumd_o,
    }
    detected: dict = {}
    for k, hm in heatmaps.items():
        detected[k] = {}
        for rep in detect_all(hm):
            detected[k].setdefault(rep.region, []).append(rep.pattern)

    dt = time.perf_counter() - t0
    hits = 0
    print("app,kernel,object,expected,detected,match")
    for app, kernel, obj, expect in EXPECTED:
        got = set(detected.get(kernel, {}).get(obj, []))
        ok = bool(got & expect)
        hits += ok
        print(f"{app},{kernel},{obj},{'|'.join(sorted(expect))},"
              f"{'|'.join(sorted(got)) or '-'},{'OK' if ok else 'MISS'}")
    # optimized variants must be clean of their original pattern
    clean = [
        ("gemm_v02", "C", "false-sharing"),
        ("spmv_zigzag", "rowPairs", "misalignment"),
        ("spt_TTMRankRBNnzKernel_reg", "Y_shr", "scratch-abuse"),
        ("gramschmidt_kernel3_opt", "qT", "strided"),
        ("find_cell_counts_opt2", "cell_count", "false-sharing"),
    ]
    for kernel, obj, pattern in clean:
        got = set(detected.get(kernel, {}).get(obj, []))
        ok = pattern not in got
        hits += ok
        print(f"(optimized),{kernel},{obj},no-{pattern},"
              f"{'|'.join(sorted(got)) or '-'},{'OK' if ok else 'MISS'}")
    total = len(EXPECTED) + len(clean)
    print(f"# pattern-table score: {hits}/{total} in {dt:.1f}s")
    return [("bench_patterns", dt * 1e6 / total, f"{hits}/{total}")]


if __name__ == "__main__":
    run()
