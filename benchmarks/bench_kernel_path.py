"""Deploy-path (Pallas-kernel) roofline estimate for the train cells.

The dry-run lowers attention as ``flash_xla`` (a CPU host cannot lower
TPU Pallas), whose per-chunk score chains stream f32 through the byte
model.  The Pallas kernel (`kernels/flash.py`, oracle-validated in
interpret mode) keeps scores/stats/accumulator in VMEM — that traffic
does not exist on the deployed path.

Measurement (not guesswork): the flash chunk loop is the only NESTED
scan in these train steps, so the attention-internal traffic is exactly
the byte tally of while bodies at depth >= 2.  This bench re-derives the
memory term with that tally removed:

    kernel_memory = hlo_bytes - depth2_bytes + qkv_streams

and reports which roofline side each train cell lands on when deployed
with the kernel.  Writes one row per arch; run AFTER the dry-run sweep.

    PYTHONPATH=src python -m benchmarks.bench_kernel_path --arch granite-8b
"""

from __future__ import annotations

import argparse
import json
import os
import re
from typing import List, Tuple

from repro.core.roofline import HBM_BW, PEAK_FLOPS_BF16

ART = os.path.normpath(
    os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun",
                 "single_16x16")
)
CHIPS = 256


def measure_depth2_bytes(arch: str) -> float:
    """Lower the cell and tally byte traffic inside nested while bodies."""
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    from repro.launch.mesh import make_production_mesh
    import repro.launch.dryrun as D
    from repro.core import hlo_cost
    from repro.parallel.context import use_rules

    mesh = make_production_mesh(multi_pod=False)
    fn, args, _, meta = D.build_cell(arch, "train_4k", mesh)
    rules = meta.pop("_rules")
    with mesh, use_rules(rules):
        co = fn.lower(*args).compile()
    model = hlo_cost.HloCostModel(co.as_text(), CHIPS)
    total = {"d2": 0.0}

    def walk(name, mult, depth):
        comp = model.comps.get(name)
        if comp is None:
            return
        for ins in comp.instrs:
            b = model._instr_cost(ins).bytes
            if ins.op in ("fusion", "call"):
                m = hlo_cost._CALL_ATTR_RE.search(ins.line)
                if m:
                    cal = m.group(1).replace("%", "").split(",")[0].strip()
                    if cal in model.comps:
                        b = model._fusion_bytes(ins, cal)
            if depth >= 2:
                total["d2"] += b * mult
            if ins.op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                mt = hlo_cost._TRIP_RE.search(ins.line)
                trips = int(mt.group(1)) if mt else 1
                if mb:
                    walk(mb.group(1), mult * trips, depth + 1)

    walk(next(n for n in model.comps if n.startswith("main")), 1.0, 0)
    return total["d2"]


def run(archs=None) -> List[Tuple[str, float, str]]:
    out = []
    archs = archs or ["granite-8b"]
    print("arch,xla_mem_ms,attn_internal_ms,kernel_mem_ms,compute_ms,"
          "collective_ms,xla_bound->kernel_bound,xla_mfu->kernel_mfu")
    for arch in archs:
        path = os.path.join(ART, f"{arch}__train_4k.json")
        if not os.path.exists(path):
            continue
        d = json.load(open(path))
        r = d["roofline"]
        d2 = measure_depth2_bytes(arch)
        mem_kernel = max(r["hlo_bytes"] - d2, 0.1 * r["hlo_bytes"]) / HBM_BW
        step0 = max(r["compute_s"], r["memory_s"], r["collective_s"])
        step1 = max(r["compute_s"], mem_kernel, r["collective_s"])
        b1 = max((("compute", r["compute_s"]), ("memory", mem_kernel),
                  ("collective", r["collective_s"])), key=lambda kv: kv[1])[0]
        mfu0 = d["model_flops"] / (step0 * CHIPS * PEAK_FLOPS_BF16)
        mfu1 = d["model_flops"] / (step1 * CHIPS * PEAK_FLOPS_BF16)
        print(f"{arch},{r['memory_s']*1e3:.0f},{d2/HBM_BW*1e3:.0f},"
              f"{mem_kernel*1e3:.0f},{r['compute_s']*1e3:.0f},"
              f"{r['collective_s']*1e3:.0f},{d['bound']}->{b1},"
              f"{100*mfu0:.1f}%->{100*mfu1:.1f}%")
        out.append((f"kernelpath_{arch}", step1 * 1e6,
                    f"{b1}-bound mfu={100*mfu1:.1f}%"))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    a = ap.parse_args()
    run(a.arch)
