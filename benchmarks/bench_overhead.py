"""Table II reproduction: profiling overhead, block-sampled vs full-trace,
plus the columnar-engine collection-throughput metric and the
sharded-vs-serial collection metric.

Paper: CUTHERMO's thread-block sampling keeps overhead at 1.07x-57x vs
NCU's 1.5x-755x.  TPU analogue: the Level-1 collector's cost is the
grid walk — block-sampling walks ONE window; the full-trace walk (the
NCU-ish exhaustive reference) walks every program.  We report, per
case-study kernel: base kernel wall time (jit, CPU), + sampled-profile
time, + full-trace time, and the two overhead ratios.

Throughput section: collection+analysis throughput (records/s and
programs/s) of the columnar engine on a FULL-GRID 4096x4096x4096 GEMM
trace, against the seed per-record engine (``repro.core._reference``).
The reference is timed on a sampled window (its cost is linear in
programs — the full grid would take minutes by construction) and its
programs/s extrapolated; pass ``--full-reference`` to time it on the
whole grid instead.  Target: >= 10x programs/s.

Sharded section: ``ShardedCollector`` (warm pool, best-of-N) against
the serial single-pass build on a full-grid GEMM trace, asserting the
merged map is bit-identical and reporting the throughput ratio.  The
requested worker count is clamped to the machine's cores (spawning 4
workers on a 1-core box measures oversubscription, not scaling), and
the headline metric is **scaling efficiency** = speedup / workers
actually used, target >= 0.8 — i.e. near-linear in workers.  The pool
is warmed outside the timed region (spawn + import paid up front, as a
long-lived profiling service would run it) and its warm-up wall time
is recorded.

Cache section: the content-addressed collection cache
(``repro.core.cache``) on the same full-grid GEMM — cold profile
(collect + store) vs warm rerun (lookup), asserting the hit is
bit-identical and recording the hit/miss counters.

Fault-recovery section: the same sharded collection with ONE injected
worker crash (``repro.core.faultinject``, crashes=1 timeouts=0) against
the clean pool run — the crash forces a pool teardown + respawn and a
shard re-delivery, the merged map must stay bit-identical, and
``fault_recovery_overhead_pct`` records the wall-time cost of that
recovery (target < 15%).

Machine-readable output: every __main__ run (and ``benchmarks/run.py``)
writes ``BENCH_collect.json`` — throughput, wall times, shard count,
speedups, git sha — next to the human-readable text.

Usage:
    PYTHONPATH=src python benchmarks/bench_overhead.py              # all
    PYTHONPATH=src python benchmarks/bench_overhead.py --throughput-only
    PYTHONPATH=src python benchmarks/bench_overhead.py --smoke      # CI
    PYTHONPATH=src python benchmarks/bench_overhead.py --workers 8
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.core import collect
from repro.core._reference import ReferenceAnalyzer, collect_reference
from repro.core.collector import ShardedCollector, analyze, sourced_spec
from repro.core.heatmap import Analyzer
from repro.core.session import heatmaps_equal
from repro.core.trace import GridSampler


def _time(fn, *args, reps=3):
    import jax

    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run() -> List[Tuple[str, float, str]]:
    import jax
    import jax.numpy as jnp

    import repro.kernels.ops as ops
    from repro.kernels.gemm import gemm_v00_spec, gemm_v01_spec
    from repro.kernels.gramschm import k3_naive_block_spec
    from repro.kernels.histogram import hist_opt_spec
    from repro.kernels.spmv import spmv_csr_spec
    from repro.kernels.ttm import ttm_scratch_spec

    key = jax.random.key(0)
    out = []
    print("kernel,base_s,sampled_s,full_s,sampled_x,full_x,records_sampled,records_full")

    cases = []

    # GEMM (the paper's worst case: trace volume ~ compute volume)
    a = jax.random.normal(key, (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.key(1), (256, 256), jnp.float32)
    cases.append((
        "gemm_v00",
        lambda: ops.matmul(a, b, variant="v00"),
        gemm_v00_spec(256, 256, 256),
        None,
    ))
    cases.append((
        "gemm_v01",
        lambda: ops.matmul(a, b, variant="v01"),
        gemm_v01_spec(256, 256, 256),
        None,
    ))

    # SpMV
    rng = np.random.default_rng(0)
    colidx = rng.integers(0, 4096, size=16384).astype(np.int32)
    vals = jax.random.normal(key, (16384 // 16, 16), jnp.float32)
    xg = jax.random.normal(key, (16384 // 16, 16), jnp.float32)
    cases.append((
        "spmv_csr",
        lambda: ops.spmv(vals, xg),
        spmv_csr_spec(16384, 4096),
        {"col_indices": colidx},
    ))

    # PASTA TTM
    tv = jax.random.normal(key, (512, 8), jnp.float32)
    tu = jax.random.normal(key, (512, 8, 32), jnp.float32)
    cases.append((
        "pasta_ttm",
        lambda: ops.ttm(tv, tu, use_scratch=True),
        ttm_scratch_spec(512, 8, 32),
        None,
    ))

    # GRAMSCHM
    q = jax.random.normal(key, (512, 512), jnp.float32)
    am = jax.random.normal(key, (512, 512), jnp.float32)
    cases.append((
        "gramschm_k3",
        lambda: ops.gramschm_k3(q, am, k=3),
        k3_naive_block_spec(512, 512, 512, k=3),
        None,
    ))

    # GPUMD histogram
    cells = jax.random.randint(key, (65536,), 0, 2048)
    cases.append((
        "gpumd_cells",
        lambda: ops.histogram(cells, 2048),
        hist_opt_spec(65536, 2048),
        None,
    ))

    for name, kernel_fn, spec, dyn in cases:
        base = _time(kernel_fn)
        t0 = time.perf_counter()
        buf_s, stats_s = collect(spec, GridSampler((0,), window=32),
                                 dynamic_context=dyn)
        sampled = time.perf_counter() - t0
        t0 = time.perf_counter()
        buf_f, stats_f = collect(spec, GridSampler(None), dynamic_context=dyn)
        full = time.perf_counter() - t0
        sx = (base + sampled) / base
        fx = (base + full) / base
        print(f"{name},{base:.4f},{sampled:.4f},{full:.4f},"
              f"{sx:.2f},{fx:.2f},{len(buf_s)},{len(buf_f)}")
        out.append((f"overhead_{name}", (base + sampled) * 1e6,
                    f"sampled {sx:.2f}x vs full {fx:.2f}x"))
    return out


def _engine_pass(collect_fn, analyzer_cls, spec, sampler):
    """One collect -> ingest -> flush pass; returns (wall_s, stats, hm)."""
    t0 = time.perf_counter()
    buf, stats = collect_fn(spec, sampler)
    an = analyzer_cls(spec.name, spec.grid, sampler.describe())
    an.ingest(buf)
    hm = an.flush()
    return time.perf_counter() - t0, stats, hm


def run_throughput(
    m: int = 4096, full_reference: bool = False
) -> List[Tuple[str, float, str]]:
    """Collection+analysis throughput: columnar engine vs seed per-record
    path on a full-grid (m x m x m) GEMM trace."""
    from repro.kernels.gemm import gemm_v01_spec

    spec = gemm_v01_spec(m, m, m)
    grid_programs = spec.grid[0]

    wall_v, stats_v, hm_v = _engine_pass(
        collect, Analyzer, spec, GridSampler(None)
    )
    prog_s_v = stats_v.programs / wall_v
    rec_s_v = stats_v.records / wall_v

    if full_reference:
        ref_sampler = GridSampler(None)
    else:
        # the reference path is linear in programs: time one 32-program
        # window and extrapolate programs/s (the full grid takes minutes
        # by construction — that slowness is what this metric measures)
        ref_sampler = GridSampler((0,), window=32)
    wall_r, stats_r, hm_r = _engine_pass(
        collect_reference, ReferenceAnalyzer, spec, ref_sampler
    )
    prog_s_r = stats_r.programs / wall_r
    rec_s_r = stats_r.records / wall_r
    speedup = prog_s_v / prog_s_r

    print(f"-- collection+analysis throughput: gemm_v01 {m}x{m}x{m}, "
          f"full grid = {grid_programs} programs --")
    print("engine,programs,records,touch_events,wall_s,programs_per_s,records_per_s")
    print(f"columnar,{stats_v.programs},{stats_v.records},"
          f"{stats_v.touch_events},{wall_v:.4f},{prog_s_v:.0f},{rec_s_v:.0f}")
    ref_tag = "full" if full_reference else "window32-extrapolated"
    print(f"reference({ref_tag}),{stats_r.programs},{stats_r.records},"
          f"-,{wall_r:.4f},{prog_s_r:.1f},{rec_s_r:.1f}")
    print(f"throughput_speedup,{speedup:.1f}x,(target >= 10x)")
    if speedup < 10:
        print("WARNING: columnar engine below the 10x throughput target",
              file=sys.stderr)
    # sanity: both engines agree on the modeled transactions they saw
    if full_reference:
        assert hm_v.sector_transactions() == hm_r.sector_transactions()
    return [
        ("collect_throughput_programs_per_s", prog_s_v,
         f"{speedup:.1f}x over per-record reference ({ref_tag})"),
        ("collect_throughput_records_per_s", rec_s_v,
         f"full-grid gemm {m}^3, {stats_v.touch_events} touch events"),
    ]


def effective_workers(requested: int) -> int:
    """Clamp a requested pool size to the machine's cores.

    Scaling is only measurable up to the core count: extra workers just
    time-slice one CPU and the 'speedup' becomes oversubscription noise.
    """
    return max(1, min(int(requested), os.cpu_count() or 1))


def run_sharded(
    m: int = 4096,
    workers: int = 4,
    reps: int = 3,
    collector: Optional[ShardedCollector] = None,
) -> List[Tuple[str, float, str]]:
    """Sharded-vs-serial collection on a full-grid (m x m x m) GEMM trace.

    Uses the row-per-program v00 ladder point — the paper's worst-case
    trace volume (one chunk per grid row) and therefore the walk a
    production profiler most wants to parallelize.  The pool is warmed
    (spawn + import paid up front) and the sharded pass takes the best
    of ``reps`` — steady-state behavior of a persistent collector.
    Asserts the merged heat map is bit-identical to the serial build.

    ``collector`` reuses an already-warm pool (the aggregator shares one
    across this bench and ``bench_tune``); when omitted a pool sized to
    ``effective_workers(workers)`` is spun up and closed here.
    """
    spec = sourced_spec("repro.kernels.gemm:gemm_v00_spec", m, m, m)
    sampler = GridSampler(None)

    t0 = time.perf_counter()
    hm_serial = analyze(spec, sampler)
    wall_serial = time.perf_counter() - t0
    programs = int(np.prod(spec.grid, dtype=np.int64))

    own = collector is None
    sc = collector or ShardedCollector(effective_workers(workers))
    try:
        warm_s = sc.warmup()
        wall_sharded = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            hm_sharded = sc.analyze(spec, sampler)
            wall_sharded = min(wall_sharded, time.perf_counter() - t0)
    finally:
        if own:
            sc.close()
    assert heatmaps_equal(hm_serial, hm_sharded), (
        "sharded merge diverged from the serial single-pass build"
    )
    used = sc.workers
    speedup = wall_serial / wall_sharded
    efficiency = speedup / used
    shard_walls = ",".join(f"{s.wall_s:.3f}" for s in hm_sharded.shards)
    print(f"-- sharded collection: gemm_v00 {m}x{m}x{m}, full grid = "
          f"{programs} programs, workers={used} "
          f"(requested {workers}, {os.cpu_count() or 1} cores) --")
    print("mode,shards,wall_s,programs_per_s")
    print(f"serial,1,{wall_serial:.4f},{programs / wall_serial:.0f}")
    print(f"sharded,{len(hm_sharded.shards)},{wall_sharded:.4f},"
          f"{programs / wall_sharded:.0f}")
    print(f"shard walls: [{shard_walls}] (bit-identical merge: yes, "
          f"pool warm-up {warm_s:.3f}s)")
    print(f"sharded_speedup,{speedup:.2f}x,"
          f"scaling_efficiency,{efficiency:.2f},(target >= 0.8x workers)")
    if efficiency < 0.8:
        print("WARNING: sharded scaling efficiency below the "
              "0.8x-workers target", file=sys.stderr)
    return [
        ("sharded_collect_programs_per_s", programs / wall_sharded,
         f"{speedup:.2f}x over serial at workers={used}, "
         f"{len(hm_sharded.shards)} shards"),
        ("sharded_scaling_efficiency", efficiency,
         f"speedup/workers at workers={used} on a warm pool "
         f"(target >= 0.8)"),
        ("pool_warmup_wall_s", warm_s,
         f"spawn+import cost paid once for {used} workers"),
        # the aggregator's CSV convention is microseconds — name it so
        ("serial_collect_wall_us", wall_serial * 1e6,
         f"full-grid gemm_v00 {m}^3 single-pass"),
    ]


def run_cached(
    m: int = 4096, collector: Optional[ShardedCollector] = None
) -> List[Tuple[str, float, str]]:
    """Content-addressed collection cache on the full-grid GEMM trace.

    Cold profile (grid walk + store) vs warm rerun (content-hash lookup)
    through the ``profile_kernel`` assembly point; the hit must be
    bit-identical to the fresh collection.
    """
    from repro.core.cache import CollectionCache
    from repro.core.session import profile_kernel

    spec = sourced_spec("repro.kernels.gemm:gemm_v00_spec", m, m, m)
    sampler = GridSampler(None)
    cache = CollectionCache()

    t0 = time.perf_counter()
    cold = profile_kernel(spec, sampler, collector=collector, cache=cache)
    wall_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = profile_kernel(spec, sampler, collector=collector, cache=cache)
    wall_warm = time.perf_counter() - t0
    assert warm.cached and not cold.cached
    assert heatmaps_equal(cold.heatmap, warm.heatmap), (
        "cache hit diverged from fresh collection"
    )
    st = cache.stats
    speedup = wall_cold / wall_warm
    print(f"-- collection cache: gemm_v00 {m}x{m}x{m}, "
          f"key {warm.cache_key[:12]}... --")
    print("pass,wall_s,cached")
    print(f"cold,{wall_cold:.4f},no")
    print(f"warm,{wall_warm:.6f},yes (bit-identical: yes)")
    print(f"cache_hit_speedup,{speedup:.0f}x "
          f"({st.hits} hits, {st.misses} misses)")
    return [
        ("collect_cache_hit_wall_us", wall_warm * 1e6,
         f"{speedup:.0f}x over the cold walk ({wall_cold:.3f}s), "
         f"bit-identical"),
        ("collect_cache_hits", float(st.hits),
         f"{st.memory_hits} memory, {st.disk_hits} disk"),
        ("collect_cache_misses", float(st.misses),
         "cold passes that walked the grid and stored"),
    ]


def run_fault_recovery(
    m: int = 4096, workers: int = 4, reps: int = 2
) -> List[Tuple[str, float, str]]:
    """Wall-time cost of recovering from one injected worker crash.

    Same full-grid GEMM walk as the sharded section, but the pool runs
    under a deterministic fault plan that kills the victim shard's
    worker on its first delivery (``os._exit`` — a real process death,
    not an exception).  The collector detects the broken pool, respawns
    it, and re-delivers the shard; the merged map must stay
    bit-identical to the clean pool run.  Both sides take the best of
    ``reps`` on a pre-warmed pool, so the overhead is pure recovery
    (teardown + respawn + re-delivery), not cold-start noise.
    """
    from repro.core.faultinject import FaultPlan

    spec = sourced_spec("repro.kernels.gemm:gemm_v00_spec", m, m, m)
    sampler = GridSampler(None)
    # a single shard collects in process (no pool, nothing to crash),
    # so this metric needs >= 2 shards even on a 1-core box — both
    # sides share the topology, so the delta is still pure recovery
    used = max(2, effective_workers(workers))

    sc = ShardedCollector(used)
    try:
        sc.warmup()
        wall_clean = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            hm_clean = sc.analyze(spec, sampler)
            wall_clean = min(wall_clean, time.perf_counter() - t0)
    finally:
        sc.close()

    plan = FaultPlan.parse("seed=7,crashes=1,timeouts=0")
    sc = ShardedCollector(used, fault_plan=plan)
    try:
        sc.warmup()
        wall_faulted = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            hm_faulted = sc.analyze(spec, sampler)
            wall_faulted = min(wall_faulted, time.perf_counter() - t0)
    finally:
        sc.close()

    assert hm_clean.faults == ()
    kinds = sorted({e.kind for e in hm_faulted.faults})
    assert "worker-crash" in kinds and "pool-rebuild" in kinds, kinds
    assert heatmaps_equal(hm_clean, hm_faulted), (
        "crash recovery diverged from the clean pool run"
    )
    overhead_pct = (wall_faulted - wall_clean) / wall_clean * 100.0
    print(f"-- fault recovery: gemm_v00 {m}x{m}x{m}, one injected "
          f"worker crash, workers={used} --")
    print("mode,wall_s,faults")
    print(f"clean,{wall_clean:.4f},none")
    print(f"crashed,{wall_faulted:.4f},{'+'.join(kinds)} "
          f"(bit-identical merge: yes)")
    print(f"fault_recovery_overhead_pct,{overhead_pct:.1f}%,"
          f"(target < 15%)")
    if overhead_pct >= 15:
        print("WARNING: crash-recovery overhead above the 15% target",
              file=sys.stderr)
    return [
        ("fault_recovery_overhead_pct", overhead_pct,
         f"one injected worker crash (pool teardown + respawn + shard "
         f"re-delivery) vs clean pool at workers={used}, bit-identical "
         f"(target < 15%)"),
    ]


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        ).stdout.strip() or "unknown"
    except Exception:  # noqa: BLE001 — benchmarks must not die on git
        return "unknown"


def write_bench_json(
    rows: List[Tuple[str, float, str]],
    path: str = "BENCH_collect.json",
    extra: Optional[dict] = None,
) -> str:
    """Write the machine-readable benchmark record (BENCH_collect.json).

    ``rows`` are the human-printed (name, value, derived) triples;
    the JSON adds the git sha and a wall-clock stamp so a trajectory of
    these files is directly plottable.
    """
    payload = {
        "bench": "collect",
        "git_sha": _git_sha(),
        "created": time.time(),
        "metrics": {
            name: {"value": value, "derived": derived}
            for name, value, derived in rows
        },
    }
    payload.update(extra or {})
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")
    return path


def run_all(
    smoke: bool = False,
    workers: int = 4,
    json_path: Optional[str] = "BENCH_collect.json",
    full_reference: bool = False,
    throughput_only: bool = False,
    collector: Optional[ShardedCollector] = None,
) -> List[Tuple[str, float, str]]:
    """Full overhead-benchmark suite + the machine-readable record.

    ``collector`` shares one warm pool across the sharded and cache
    sections (and, via ``benchmarks/run.py``, with ``bench_tune``).
    """
    size = 1024 if smoke else 4096
    results = run_throughput(m=size, full_reference=full_reference)
    shard_m = 2048 if smoke else 4096
    results += run_sharded(m=shard_m, workers=workers, collector=collector)
    results += run_cached(m=shard_m, collector=collector)
    results += run_fault_recovery(m=shard_m, workers=workers)
    if not throughput_only and not smoke:
        results += run()
    if json_path:
        write_bench_json(
            results, json_path,
            extra={
                "smoke": smoke,
                "workers": effective_workers(workers),
                "workers_requested": workers,
            },
        )
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI")
    ap.add_argument("--workers", type=int, default=4,
                    help="shard-pool size for the sharded metric")
    ap.add_argument("--full-reference", action="store_true",
                    help="time the per-record reference on the full grid")
    ap.add_argument("--throughput-only", action="store_true",
                    help="skip the per-kernel Table II section")
    args = ap.parse_args()
    run_all(
        smoke=args.smoke,
        workers=args.workers,
        full_reference=args.full_reference,
        throughput_only=args.throughput_only,
    )
