"""Table II reproduction: profiling overhead, block-sampled vs full-trace.

Paper: CUTHERMO's thread-block sampling keeps overhead at 1.07x-57x vs
NCU's 1.5x-755x.  TPU analogue: the Level-1 collector's cost is the
grid walk — block-sampling walks ONE window; the full-trace walk (the
NCU-ish exhaustive reference) walks every program.  We report, per
case-study kernel: base kernel wall time (jit, CPU), + sampled-profile
time, + full-trace time, and the two overhead ratios.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import collect
from repro.core.trace import GridSampler
import repro.kernels.ops as ops
from repro.kernels.gemm import gemm_v00_spec, gemm_v01_spec
from repro.kernels.gramschm import k3_naive_block_spec
from repro.kernels.histogram import hist_opt_spec
from repro.kernels.spmv import spmv_csr_spec
from repro.kernels.ttm import ttm_scratch_spec


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run() -> List[Tuple[str, float, str]]:
    key = jax.random.key(0)
    out = []
    print("kernel,base_s,sampled_s,full_s,sampled_x,full_x,records_sampled,records_full")

    cases = []

    # GEMM (the paper's worst case: trace volume ~ compute volume)
    a = jax.random.normal(key, (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.key(1), (256, 256), jnp.float32)
    cases.append((
        "gemm_v00",
        lambda: ops.matmul(a, b, variant="v00"),
        gemm_v00_spec(256, 256, 256),
        None,
    ))
    cases.append((
        "gemm_v01",
        lambda: ops.matmul(a, b, variant="v01"),
        gemm_v01_spec(256, 256, 256),
        None,
    ))

    # SpMV
    rng = np.random.default_rng(0)
    colidx = rng.integers(0, 4096, size=16384).astype(np.int32)
    vals = jax.random.normal(key, (16384 // 16, 16), jnp.float32)
    xg = jax.random.normal(key, (16384 // 16, 16), jnp.float32)
    cases.append((
        "spmv_csr",
        lambda: ops.spmv(vals, xg),
        spmv_csr_spec(16384, 4096),
        {"col_indices": colidx},
    ))

    # PASTA TTM
    tv = jax.random.normal(key, (512, 8), jnp.float32)
    tu = jax.random.normal(key, (512, 8, 32), jnp.float32)
    cases.append((
        "pasta_ttm",
        lambda: ops.ttm(tv, tu, use_scratch=True),
        ttm_scratch_spec(512, 8, 32),
        None,
    ))

    # GRAMSCHM
    q = jax.random.normal(key, (512, 512), jnp.float32)
    am = jax.random.normal(key, (512, 512), jnp.float32)
    cases.append((
        "gramschm_k3",
        lambda: ops.gramschm_k3(q, am, k=3),
        k3_naive_block_spec(512, 512, 512, k=3),
        None,
    ))

    # GPUMD histogram
    cells = jax.random.randint(key, (65536,), 0, 2048)
    cases.append((
        "gpumd_cells",
        lambda: ops.histogram(cells, 2048),
        hist_opt_spec(65536, 2048),
        None,
    ))

    for name, kernel_fn, spec, dyn in cases:
        base = _time(kernel_fn)
        t0 = time.perf_counter()
        buf_s, stats_s = collect(spec, GridSampler((0,), window=32),
                                 dynamic_context=dyn)
        sampled = time.perf_counter() - t0
        t0 = time.perf_counter()
        buf_f, stats_f = collect(spec, GridSampler(None), dynamic_context=dyn)
        full = time.perf_counter() - t0
        sx = (base + sampled) / base
        fx = (base + full) / base
        print(f"{name},{base:.4f},{sampled:.4f},{full:.4f},"
              f"{sx:.2f},{fx:.2f},{len(buf_s)},{len(buf_f)}")
        out.append((f"overhead_{name}", (base + sampled) * 1e6,
                    f"sampled {sx:.2f}x vs full {fx:.2f}x"))
    return out


if __name__ == "__main__":
    run()
