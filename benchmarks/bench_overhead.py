"""Table II reproduction: profiling overhead, block-sampled vs full-trace,
plus the columnar-engine collection-throughput metric.

Paper: CUTHERMO's thread-block sampling keeps overhead at 1.07x-57x vs
NCU's 1.5x-755x.  TPU analogue: the Level-1 collector's cost is the
grid walk — block-sampling walks ONE window; the full-trace walk (the
NCU-ish exhaustive reference) walks every program.  We report, per
case-study kernel: base kernel wall time (jit, CPU), + sampled-profile
time, + full-trace time, and the two overhead ratios.

Throughput section: collection+analysis throughput (records/s and
programs/s) of the columnar engine on a FULL-GRID 4096x4096x4096 GEMM
trace, against the seed per-record engine (``repro.core._reference``).
The reference is timed on a sampled window (its cost is linear in
programs — the full grid would take minutes by construction) and its
programs/s extrapolated; pass ``--full-reference`` to time it on the
whole grid instead.  Target: >= 10x programs/s.

Usage:
    PYTHONPATH=src python benchmarks/bench_overhead.py              # both
    PYTHONPATH=src python benchmarks/bench_overhead.py --throughput-only
    PYTHONPATH=src python benchmarks/bench_overhead.py --smoke      # CI
"""

from __future__ import annotations

import sys
import time
from typing import List, Tuple

import numpy as np

from repro.core import collect
from repro.core._reference import ReferenceAnalyzer, collect_reference
from repro.core.heatmap import Analyzer
from repro.core.trace import GridSampler


def _time(fn, *args, reps=3):
    import jax

    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run() -> List[Tuple[str, float, str]]:
    import jax
    import jax.numpy as jnp

    import repro.kernels.ops as ops
    from repro.kernels.gemm import gemm_v00_spec, gemm_v01_spec
    from repro.kernels.gramschm import k3_naive_block_spec
    from repro.kernels.histogram import hist_opt_spec
    from repro.kernels.spmv import spmv_csr_spec
    from repro.kernels.ttm import ttm_scratch_spec

    key = jax.random.key(0)
    out = []
    print("kernel,base_s,sampled_s,full_s,sampled_x,full_x,records_sampled,records_full")

    cases = []

    # GEMM (the paper's worst case: trace volume ~ compute volume)
    a = jax.random.normal(key, (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.key(1), (256, 256), jnp.float32)
    cases.append((
        "gemm_v00",
        lambda: ops.matmul(a, b, variant="v00"),
        gemm_v00_spec(256, 256, 256),
        None,
    ))
    cases.append((
        "gemm_v01",
        lambda: ops.matmul(a, b, variant="v01"),
        gemm_v01_spec(256, 256, 256),
        None,
    ))

    # SpMV
    rng = np.random.default_rng(0)
    colidx = rng.integers(0, 4096, size=16384).astype(np.int32)
    vals = jax.random.normal(key, (16384 // 16, 16), jnp.float32)
    xg = jax.random.normal(key, (16384 // 16, 16), jnp.float32)
    cases.append((
        "spmv_csr",
        lambda: ops.spmv(vals, xg),
        spmv_csr_spec(16384, 4096),
        {"col_indices": colidx},
    ))

    # PASTA TTM
    tv = jax.random.normal(key, (512, 8), jnp.float32)
    tu = jax.random.normal(key, (512, 8, 32), jnp.float32)
    cases.append((
        "pasta_ttm",
        lambda: ops.ttm(tv, tu, use_scratch=True),
        ttm_scratch_spec(512, 8, 32),
        None,
    ))

    # GRAMSCHM
    q = jax.random.normal(key, (512, 512), jnp.float32)
    am = jax.random.normal(key, (512, 512), jnp.float32)
    cases.append((
        "gramschm_k3",
        lambda: ops.gramschm_k3(q, am, k=3),
        k3_naive_block_spec(512, 512, 512, k=3),
        None,
    ))

    # GPUMD histogram
    cells = jax.random.randint(key, (65536,), 0, 2048)
    cases.append((
        "gpumd_cells",
        lambda: ops.histogram(cells, 2048),
        hist_opt_spec(65536, 2048),
        None,
    ))

    for name, kernel_fn, spec, dyn in cases:
        base = _time(kernel_fn)
        t0 = time.perf_counter()
        buf_s, stats_s = collect(spec, GridSampler((0,), window=32),
                                 dynamic_context=dyn)
        sampled = time.perf_counter() - t0
        t0 = time.perf_counter()
        buf_f, stats_f = collect(spec, GridSampler(None), dynamic_context=dyn)
        full = time.perf_counter() - t0
        sx = (base + sampled) / base
        fx = (base + full) / base
        print(f"{name},{base:.4f},{sampled:.4f},{full:.4f},"
              f"{sx:.2f},{fx:.2f},{len(buf_s)},{len(buf_f)}")
        out.append((f"overhead_{name}", (base + sampled) * 1e6,
                    f"sampled {sx:.2f}x vs full {fx:.2f}x"))
    return out


def _engine_pass(collect_fn, analyzer_cls, spec, sampler):
    """One collect -> ingest -> flush pass; returns (wall_s, stats, hm)."""
    t0 = time.perf_counter()
    buf, stats = collect_fn(spec, sampler)
    an = analyzer_cls(spec.name, spec.grid, sampler.describe())
    an.ingest(buf)
    hm = an.flush()
    return time.perf_counter() - t0, stats, hm


def run_throughput(
    m: int = 4096, full_reference: bool = False
) -> List[Tuple[str, float, str]]:
    """Collection+analysis throughput: columnar engine vs seed per-record
    path on a full-grid (m x m x m) GEMM trace."""
    from repro.kernels.gemm import gemm_v01_spec

    spec = gemm_v01_spec(m, m, m)
    grid_programs = spec.grid[0]

    wall_v, stats_v, hm_v = _engine_pass(
        collect, Analyzer, spec, GridSampler(None)
    )
    prog_s_v = stats_v.programs / wall_v
    rec_s_v = stats_v.records / wall_v

    if full_reference:
        ref_sampler = GridSampler(None)
    else:
        # the reference path is linear in programs: time one 32-program
        # window and extrapolate programs/s (the full grid takes minutes
        # by construction — that slowness is what this metric measures)
        ref_sampler = GridSampler((0,), window=32)
    wall_r, stats_r, hm_r = _engine_pass(
        collect_reference, ReferenceAnalyzer, spec, ref_sampler
    )
    prog_s_r = stats_r.programs / wall_r
    rec_s_r = stats_r.records / wall_r
    speedup = prog_s_v / prog_s_r

    print(f"-- collection+analysis throughput: gemm_v01 {m}x{m}x{m}, "
          f"full grid = {grid_programs} programs --")
    print("engine,programs,records,touch_events,wall_s,programs_per_s,records_per_s")
    print(f"columnar,{stats_v.programs},{stats_v.records},"
          f"{stats_v.touch_events},{wall_v:.4f},{prog_s_v:.0f},{rec_s_v:.0f}")
    ref_tag = "full" if full_reference else "window32-extrapolated"
    print(f"reference({ref_tag}),{stats_r.programs},{stats_r.records},"
          f"-,{wall_r:.4f},{prog_s_r:.1f},{rec_s_r:.1f}")
    print(f"throughput_speedup,{speedup:.1f}x,(target >= 10x)")
    if speedup < 10:
        print("WARNING: columnar engine below the 10x throughput target",
              file=sys.stderr)
    # sanity: both engines agree on the modeled transactions they saw
    if full_reference:
        assert hm_v.sector_transactions() == hm_r.sector_transactions()
    return [
        ("collect_throughput_programs_per_s", prog_s_v,
         f"{speedup:.1f}x over per-record reference ({ref_tag})"),
        ("collect_throughput_records_per_s", rec_s_v,
         f"full-grid gemm {m}^3, {stats_v.touch_events} touch events"),
    ]


if __name__ == "__main__":
    argv = set(sys.argv[1:])
    smoke = "--smoke" in argv
    size = 1024 if smoke else 4096
    results = run_throughput(m=size, full_reference="--full-reference" in argv)
    if "--throughput-only" not in argv and not smoke:
        results += run()
