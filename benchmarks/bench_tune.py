"""Autotuner benchmark: close the tuning loop unattended per family.

The paper's headline result (up to 721.79% speedup) comes from walking
the profile -> optimize -> re-profile loop by hand; ``repro.core.tuner``
walks it programmatically.  This bench runs ``tune`` from the naive
variant of every laddered kernel family and records, per family:

* modeled-transaction speedup of the winning variant (the Table III
  currency),
* which patterns the trajectory fixed,
* how many candidates the budget bought and the wall time spent,
* how many candidates the static lint pre-screen skipped outright
  (``tune_static_skipped`` — proved worse from the spec, never traced).

The acceptance bar mirrors the repo's tuning-loop contract: at least
**3 families** must end on a variant with strictly fewer sector
transactions AND at least one fixed pattern — fully unattended.

Every family tunes into a (throwaway) ``ProfileSession``, so each
recorded step carries the ``iteration`` name that stored it — the
trajectory in BENCH_tune.json links back to session provenance exactly
like ``tuner.trajectories_from_session`` recovers it.

Cache section: all families share one content-addressed
``CollectionCache`` (and, under ``benchmarks/run.py``, the warm
``ShardedCollector`` pool from the collect bench).  After the cold
pass, one family is re-tuned warm: the rerun must perform strictly
fewer fresh traces than candidates tried (repeated candidates are
served bit-identical cached heat maps), and the hit/miss counters are
recorded in the metrics block.

Machine-readable output: every ``__main__`` run (and
``benchmarks/run.py``) writes ``BENCH_tune.json`` — per-family speedup,
candidates tried, wall time, full step trajectories, git sha.

Usage:
    PYTHONPATH=src python benchmarks/bench_tune.py            # all families
    PYTHONPATH=src python benchmarks/bench_tune.py --smoke    # CI subset
    PYTHONPATH=src python benchmarks/bench_tune.py --budget 4
"""

from __future__ import annotations

import tempfile
from typing import List, Optional, Tuple

#: Ladder families the unattended loop is expected to close.  (cuszp,
#: flash, gmm and ssd have single-variant ladders — the tuner still
#: runs on them, but they are not part of the acceptance bar.)  The
#: serving-shaped families exercise decode/prefill scenarios: their
#: data-dependent rungs win on strictly fewer transfers, then the
#: generated candidates fix the residual hot patterns on top.
FAMILIES = ("gemm", "spmv", "histogram", "gramschm", "ttm",
            "ragged_flash", "paged_attn")

#: Families the CI smoke subset tunes (small grids, < 1 s each).
SMOKE_FAMILIES = ("gemm", "gramschm", "ttm")

#: Minimum count of families that must reach strictly fewer sector
#: transactions with at least one fixed pattern.  (The smoke subset
#: includes ttm, whose register-fusion fix keeps HBM traffic equal by
#: design, so its bar is one lower.)
MIN_CLOSED = 3
MIN_CLOSED_SMOKE = 2


def run(
    families: Tuple[str, ...] = FAMILIES,
    budget: int = 6,
    seed: int = 0,
    min_closed: int = MIN_CLOSED,
    collector=None,
) -> Tuple[List[Tuple[str, float, str]], List[dict]]:
    """Tune every family; returns (printed rows, trajectory dicts).

    Runs inside a throwaway ``ProfileSession`` so every recorded step
    carries its ``iteration`` provenance, with one shared
    ``CollectionCache`` across all families.  ``collector`` reuses an
    already-warm shard pool (``benchmarks/run.py`` passes the one the
    collect bench warmed).  After the cold pass the first family is
    re-tuned warm to record the cache-bounded loop: fresh traces
    strictly fewer than candidates tried, hits bit-identical.
    """
    from repro.core.cache import CollectionCache
    from repro.core.session import ProfileSession, heatmaps_equal
    from repro.core.tuner import tune

    cache = CollectionCache()
    rows: List[Tuple[str, float, str]] = []
    results: List[dict] = []
    cold: List = []
    with tempfile.TemporaryDirectory(prefix="bench-tune-") as tmp:
        sess = ProfileSession(tmp, cache=cache)
        print("family,speedup,candidates,fixed,converged,wall_s")
        for fam in families:
            res = tune(
                fam, budget=budget, seed=seed, session=sess,
                collector=collector, cache=cache,
            )
            cold.append(res)
            d = res.as_dict()
            results.append(d)
            fixed = ";".join(f"{p}@{r}" for r, p in res.fixed_patterns) or "-"
            print(
                f"{fam},{res.speedup:.2f}x,{len(res.steps)},{fixed},"
                f"{res.converged},{res.wall_s:.2f}"
            )
            rows.append(
                (
                    f"tune_{fam}_speedup",
                    res.speedup,
                    f"{res.baseline.transactions}->{res.best.transactions} "
                    f"transfers via {res.best_label} "
                    f"({len(res.steps)} candidates, "
                    f"{len(res.fixed_patterns)} patterns fixed)",
                )
            )

        # warm rerun: same family, same seed, same shared cache — every
        # repeated candidate must be served from the cache, so the rerun
        # performs strictly fewer fresh traces than candidates it tries
        fam = families[0]
        miss_before = cache.stats.misses
        hit_before = cache.stats.hits
        warm = tune(
            fam, budget=budget, seed=seed, session=sess,
            collector=collector, cache=cache,
        )
        fresh = cache.stats.misses - miss_before
        hits = cache.stats.hits - hit_before
        tried = len(warm.steps) + 1  # candidates + the baseline profile
        assert fresh < tried, (
            f"warm rerun of {fam} re-traced {fresh}/{tried} profiles — "
            "the collection cache is not bounding the tune loop"
        )
        assert heatmaps_equal(warm.best.heatmap, cold[0].best.heatmap), (
            "cached tune rerun diverged from the cold trajectory"
        )
        print(
            f"warm rerun ({fam}): {tried} profiles, {fresh} fresh traces, "
            f"{hits} cache hits (bit-identical: yes)"
        )
    rows.append(
        (
            "tune_rerun_candidates_tried",
            float(tried),
            f"warm {fam} rerun: candidate profiles + baseline",
        )
    )
    rows.append(
        (
            "tune_rerun_fresh_traces",
            float(fresh),
            f"grid walks the warm rerun still performed "
            f"(target < {tried}; cache hits are bit-identical)",
        )
    )
    rows.append(
        (
            "tune_cache_hits",
            float(cache.stats.hits),
            f"{cache.stats.misses} misses across "
            f"{len(families)} cold families + 1 warm rerun",
        )
    )
    # static pre-screen accounting: candidates the linter proved worse
    # and the loop therefore never traced (tuner static_skipped
    # provenance).  The registry is expected to exercise the screen —
    # gemm's transpose candidates and gramschm's pin(qT) are statically
    # worse by construction — so a zero here means the pre-screen
    # stopped firing, not that there was nothing to skip.
    skipped = sum(len(d["static_skipped"]) for d in results)
    assert skipped >= 1, (
        "no candidate was statically pre-screened across "
        f"{len(families)} families — the tuner's lint pre-screen is dead"
    )
    rows.append(
        (
            "tune_static_skipped",
            float(skipped),
            "candidates the static linter proved worse — never traced, "
            "zero budget spent",
        )
    )
    print(f"static prescreen: {skipped} candidates never traced")
    closed = sum(
        1 for d in results if d["improved"] and d["fixed"]
    )
    target = min(min_closed, len(families))
    rows.append(
        (
            "tune_families_closed",
            float(closed),
            f"families ending with strictly fewer transactions AND a "
            f"fixed pattern (target >= {target})",
        )
    )
    if closed < target:
        import sys

        print(
            f"WARNING: only {closed} families closed the loop "
            f"(target {target}) — tuning-loop regression",
            file=sys.stderr,
        )
    return rows, results


def write_bench_json(
    rows: List[Tuple[str, float, str]],
    results: List[dict],
    path: str = "BENCH_tune.json",
    extra: Optional[dict] = None,
) -> str:
    """Write the machine-readable record (BENCH_tune.json).

    Delegates the envelope (metrics map, git sha, wall-clock stamp) to
    ``bench_overhead.write_bench_json`` — one writer, two records —
    overriding the bench tag and attaching the full per-family
    trajectories.
    """
    try:  # package import (benchmarks/run.py) vs direct-script run
        from benchmarks.bench_overhead import write_bench_json as _record
    except ImportError:
        from bench_overhead import write_bench_json as _record
    payload_extra = {"bench": "tune", "families": results}
    payload_extra.update(extra or {})
    return _record(rows, path, extra=payload_extra)


def run_all(
    smoke: bool = False,
    budget: int = 6,
    seed: int = 0,
    json_path: Optional[str] = "BENCH_tune.json",
    collector=None,
) -> List[Tuple[str, float, str]]:
    """Whole tuning bench + the machine-readable record.

    ``collector`` reuses an already-warm ``ShardedCollector`` pool
    (``benchmarks/run.py`` shares the collect bench's).
    """
    families = SMOKE_FAMILIES if smoke else FAMILIES
    rows, results = run(
        families=families, budget=budget, seed=seed,
        min_closed=MIN_CLOSED_SMOKE if smoke else MIN_CLOSED,
        collector=collector,
    )
    if json_path:
        write_bench_json(
            rows, results, json_path,
            extra={"smoke": smoke, "budget": budget, "seed": seed},
        )
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset (3 fast families)")
    ap.add_argument("--budget", type=int, default=6,
                    help="candidate re-profiles per family (default: 6)")
    ap.add_argument("--seed", type=int, default=0,
                    help="candidate tie-break seed")
    args = ap.parse_args()
    run_all(smoke=args.smoke, budget=args.budget, seed=args.seed)
