"""Autotuner benchmark: close the tuning loop unattended per family.

The paper's headline result (up to 721.79% speedup) comes from walking
the profile -> optimize -> re-profile loop by hand; ``repro.core.tuner``
walks it programmatically.  This bench runs ``tune`` from the naive
variant of every laddered kernel family and records, per family:

* modeled-transaction speedup of the winning variant (the Table III
  currency),
* which patterns the trajectory fixed,
* how many candidates the budget bought and the wall time spent.

The acceptance bar mirrors the repo's tuning-loop contract: at least
**3 families** must end on a variant with strictly fewer sector
transactions AND at least one fixed pattern — fully unattended.

Machine-readable output: every ``__main__`` run (and
``benchmarks/run.py``) writes ``BENCH_tune.json`` — per-family speedup,
candidates tried, wall time, full step trajectories, git sha.

Usage:
    PYTHONPATH=src python benchmarks/bench_tune.py            # all families
    PYTHONPATH=src python benchmarks/bench_tune.py --smoke    # CI subset
    PYTHONPATH=src python benchmarks/bench_tune.py --budget 4
"""

from __future__ import annotations

from typing import List, Optional, Tuple

#: Ladder families the unattended loop is expected to close.  (cuszp,
#: flash, gmm and ssd have single-variant ladders — the tuner still
#: runs on them, but they are not part of the acceptance bar.)
FAMILIES = ("gemm", "spmv", "histogram", "gramschm", "ttm")

#: Families the CI smoke subset tunes (small grids, < 1 s each).
SMOKE_FAMILIES = ("gemm", "gramschm", "ttm")

#: Minimum count of families that must reach strictly fewer sector
#: transactions with at least one fixed pattern.  (The smoke subset
#: includes ttm, whose register-fusion fix keeps HBM traffic equal by
#: design, so its bar is one lower.)
MIN_CLOSED = 3
MIN_CLOSED_SMOKE = 2


def run(
    families: Tuple[str, ...] = FAMILIES,
    budget: int = 6,
    seed: int = 0,
    min_closed: int = MIN_CLOSED,
) -> Tuple[List[Tuple[str, float, str]], List[dict]]:
    """Tune every family; returns (printed rows, trajectory dicts)."""
    from repro.core.tuner import tune

    rows: List[Tuple[str, float, str]] = []
    results: List[dict] = []
    print("family,speedup,candidates,fixed,converged,wall_s")
    for fam in families:
        res = tune(fam, budget=budget, seed=seed)
        d = res.as_dict()
        results.append(d)
        fixed = ";".join(f"{p}@{r}" for r, p in res.fixed_patterns) or "-"
        print(
            f"{fam},{res.speedup:.2f}x,{len(res.steps)},{fixed},"
            f"{res.converged},{res.wall_s:.2f}"
        )
        rows.append(
            (
                f"tune_{fam}_speedup",
                res.speedup,
                f"{res.baseline.transactions}->{res.best.transactions} "
                f"transfers via {res.best_label} "
                f"({len(res.steps)} candidates, "
                f"{len(res.fixed_patterns)} patterns fixed)",
            )
        )
    closed = sum(
        1 for d in results if d["improved"] and d["fixed"]
    )
    target = min(min_closed, len(families))
    rows.append(
        (
            "tune_families_closed",
            float(closed),
            f"families ending with strictly fewer transactions AND a "
            f"fixed pattern (target >= {target})",
        )
    )
    if closed < target:
        import sys

        print(
            f"WARNING: only {closed} families closed the loop "
            f"(target {target}) — tuning-loop regression",
            file=sys.stderr,
        )
    return rows, results


def write_bench_json(
    rows: List[Tuple[str, float, str]],
    results: List[dict],
    path: str = "BENCH_tune.json",
    extra: Optional[dict] = None,
) -> str:
    """Write the machine-readable record (BENCH_tune.json).

    Delegates the envelope (metrics map, git sha, wall-clock stamp) to
    ``bench_overhead.write_bench_json`` — one writer, two records —
    overriding the bench tag and attaching the full per-family
    trajectories.
    """
    try:  # package import (benchmarks/run.py) vs direct-script run
        from benchmarks.bench_overhead import write_bench_json as _record
    except ImportError:
        from bench_overhead import write_bench_json as _record
    payload_extra = {"bench": "tune", "families": results}
    payload_extra.update(extra or {})
    return _record(rows, path, extra=payload_extra)


def run_all(
    smoke: bool = False,
    budget: int = 6,
    seed: int = 0,
    json_path: Optional[str] = "BENCH_tune.json",
) -> List[Tuple[str, float, str]]:
    """Whole tuning bench + the machine-readable record."""
    families = SMOKE_FAMILIES if smoke else FAMILIES
    rows, results = run(
        families=families, budget=budget, seed=seed,
        min_closed=MIN_CLOSED_SMOKE if smoke else MIN_CLOSED,
    )
    if json_path:
        write_bench_json(
            rows, results, json_path,
            extra={"smoke": smoke, "budget": budget, "seed": seed},
        )
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset (3 fast families)")
    ap.add_argument("--budget", type=int, default=6,
                    help="candidate re-profiles per family (default: 6)")
    ap.add_argument("--seed", type=int, default=0,
                    help="candidate tie-break seed")
    args = ap.parse_args()
    run_all(smoke=args.smoke, budget=args.budget, seed=args.seed)
