"""Benchmark aggregator: one section per paper table + roofline.

    PYTHONPATH=src python -m benchmarks.run

Prints ``name,us_per_call,derived`` CSV rows per bench, as required.
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import bench_overhead, bench_patterns, bench_roofline, bench_speedup

    rows = []
    for name, mod in (
        ("patterns (paper Table I)", bench_patterns),
        ("overhead (paper Table II)", bench_overhead),
        ("speedup (paper Table III)", bench_speedup),
        ("roofline (§Roofline)", bench_roofline),
    ):
        print(f"\n===== {name} =====")
        try:
            rows.extend(mod.run())
        except Exception as e:  # noqa: BLE001 — keep the suite going
            print(f"# FAILED: {e!r}")
            rows.append((name, 0.0, f"FAILED {e!r}"))

    print("\n===== summary: name,us_per_call,derived =====")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
