"""Benchmark aggregator: one section per paper table + roofline.

    PYTHONPATH=src python -m benchmarks.run

Prints ``name,us_per_call,derived`` CSV rows per bench, as required,
and writes the machine-readable records — ``BENCH_collect.json`` for
the collection benchmarks (throughput, wall times, shard count, git
sha) and ``BENCH_tune.json`` for the autotuner loop (per-family
speedups, candidates tried, trajectories) — so the BENCH_* trajectory
can be tracked across commits without scraping stdout.
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (
        bench_overhead,
        bench_patterns,
        bench_roofline,
        bench_speedup,
        bench_tune,
    )
    from repro.core.collector import ShardedCollector

    # ONE warm shard pool for the whole suite: the collect bench pays
    # the spawn+import cost once (and records it), the tune bench then
    # profiles its candidates on the same warm workers
    collector = ShardedCollector(bench_overhead.effective_workers(4))
    rows = []
    try:
        for name, runner in (
            ("patterns (paper Table I)", bench_patterns.run),
            # run_all = Table II + collection throughput +
            # sharded-vs-serial + collection cache; it also writes the
            # BENCH_collect.json record
            (
                "overhead (paper Table II)",
                lambda: bench_overhead.run_all(collector=collector),
            ),
            ("speedup (paper Table III)", bench_speedup.run),
            # closes the tuning loop per family on the same warm pool;
            # writes BENCH_tune.json
            (
                "autotuner (closed loop)",
                lambda: bench_tune.run_all(collector=collector),
            ),
            ("roofline (§Roofline)", bench_roofline.run),
        ):
            print(f"\n===== {name} =====")
            try:
                rows.extend(runner())
            except Exception as e:  # noqa: BLE001 — keep the suite going
                print(f"# FAILED: {e!r}")
                rows.append((name, 0.0, f"FAILED {e!r}"))
    finally:
        collector.close()

    print("\n===== summary: name,us_per_call,derived =====")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
