"""§Roofline: per (arch x shape x mesh) three-term table from the dry-run
artifacts (artifacts/dryrun/<mesh>/<arch>__<shape>.json)."""

from __future__ import annotations

import json
import os
from typing import List, Tuple

ART = os.path.normpath(
    os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
)


def run() -> List[Tuple[str, float, str]]:
    out = []
    if not os.path.isdir(ART):
        print("# no dry-run artifacts found; run repro.launch.dryrun --all first")
        return [("bench_roofline", 0.0, "no-artifacts")]
    print("mesh,arch,shape,GiB/chip,compute_ms,memory_ms,collective_ms,bound,"
          "useful_flop_pct,mfu_pct")
    for mesh_name in sorted(os.listdir(ART)):
        mdir = os.path.join(ART, mesh_name)
        if not os.path.isdir(mdir):
            continue
        for fn in sorted(os.listdir(mdir)):
            if not fn.endswith(".json"):
                continue
            with open(os.path.join(mdir, fn)) as f:
                d = json.load(f)
            r = d["roofline"]
            gib = d.get("per_device_bytes", 0) / 2**30
            print(
                f"{mesh_name},{d['arch']},{d['shape']},{gib:.2f},"
                f"{r['compute_s']*1e3:.2f},{r['memory_s']*1e3:.2f},"
                f"{r['collective_s']*1e3:.2f},{d['bound']},"
                f"{100*r['useful_flop_fraction']:.0f},{100*r['mfu']:.2f}"
            )
            out.append((
                f"roofline_{mesh_name}_{d['arch']}_{d['shape']}",
                r["step_s"] * 1e6,
                f"{d['bound']}-bound mfu={100*r['mfu']:.2f}%",
            ))
    return out


if __name__ == "__main__":
    run()
