"""Table III reproduction: optimization speedups guided by the heat map.

Two measurements per case study:
  * modeled HBM transaction ratio (the profiler's own currency — exact,
    hardware-independent), vs the paper's reported cycle speedups;
  * measured CPU wall time of the jit'd kernels where the variants do
    different real work (interpret-mode Pallas; directional only).

Paper Table III (A4500/RTX4090): gemm_v00 721.79%/682.82%, gemm_v01
26.07%/20.27%, SpMV 1.85%/1.97%, PASTA 163.56%/159.62%, GRAMSCHM k3
23.18%/19.81%.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analyze
from repro.core.trace import GridSampler
import repro.kernels.ops as ops
from repro.kernels.gemm import gemm_v00_spec, gemm_v01_spec, gemm_v02_spec
from repro.kernels.gramschm import k3_naive_block_spec, k3_opt_spec
from repro.kernels.histogram import hist_naive_spec, hist_opt2_spec
from repro.kernels.spmv import spmv_csr_spec, spmv_zigzag_spec
from repro.kernels.ttm import ttm_fused_spec, ttm_scratch_spec


def _time(fn, reps=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def run() -> List[Tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    out = []
    print("case,tx_before,tx_after,modeled_speedup_pct,paper_pct,wall_before_s,wall_after_s")

    S = GridSampler((0,), window=32)
    rows = []

    # GEMM v00 -> v01 (paper: +721.79%).  The sampled windows produce
    # DIFFERENT amounts of C (32 rows vs 256 rows), so transactions are
    # normalized per produced C row (tx-per-unit-work == the cycle ratio).
    hm0 = analyze(gemm_v00_spec(1024, 1024, 1024), S)
    hm1 = analyze(gemm_v01_spec(1024, 1024, 1024), S)
    a = jax.random.normal(jax.random.key(0), (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.key(1), (256, 256), jnp.float32)
    w0 = _time(lambda: ops.matmul(a, b, variant="v00"))
    w1 = _time(lambda: ops.matmul(a, b, variant="v01"))
    rows.append(("gemm_v00->v01",
                 hm0.sector_transactions() / 32,
                 hm1.sector_transactions() / 256, 721.79, w0, w1))

    # GEMM v01 -> v02 (paper: +26.07%; see EXPERIMENTS.md — on GPU the
    # gain was capped by a 99.2% L1 hit rate absorbing B re-fetches; TPU
    # has no data cache, so explicit tiling saves the full traffic)
    hm2 = analyze(gemm_v02_spec(1024, 1024, 1024), GridSampler(None))
    w2 = _time(lambda: ops.matmul(a, b, variant="v02", bm=64, bn=64, bk=64))
    rows.append(("gemm_v01->v02",
                 hm1.sector_transactions() / 256,
                 hm2.sector_transactions() / 1024, 26.07, w1, w2))

    # SpMV misaligned -> zigzag (paper: +1.85% whole-kernel — the offsets
    # are a small slice of total traffic; compare whole-kernel tx)
    colidx = rng.integers(0, 36417, size=65536).astype(np.int32)
    hm_s = analyze(spmv_csr_spec(65536, 36417), S,
                   dynamic_context={"col_indices": colidx})
    hm_z = analyze(spmv_zigzag_spec(65536, 36417), S,
                   dynamic_context={"col_indices": colidx})
    rows.append(("spmv_csr", hm_s.sector_transactions(),
                 hm_z.sector_transactions(), 1.85, None, None))

    # PASTA scratch -> registers (paper: +163.56%)
    tv = jax.random.normal(jax.random.key(2), (512, 8), jnp.float32)
    tu = jax.random.normal(jax.random.key(3), (512, 8, 32), jnp.float32)
    ws = _time(lambda: ops.ttm(tv, tu, use_scratch=True))
    wf = _time(lambda: ops.ttm(tv, tu, use_scratch=False))
    # scratch round-trip bytes modeled as the saved traffic
    hm_ts = analyze(ttm_scratch_spec(512, 8, 32), S)
    hm_tf = analyze(ttm_fused_spec(512, 8, 32), S)
    scratch_words = sum(
        sum(r.word_temps) for rh in hm_ts.regions
        if rh.region.space == "vmem_scratch" for r in rh.rows
    )
    rows.append(("pasta_ttm", hm_ts.sector_transactions() + scratch_words // 8,
                 hm_tf.sector_transactions(), 163.56, ws, wf))

    # GRAMSCHM k3 naive -> transposed (paper: +23.18%): whole-kernel tx
    # (q improves 64x but shares the kernel with the a/r streams)
    hm_g0 = analyze(k3_naive_block_spec(512, 512, 512, k=3), GridSampler(None))
    hm_g1 = analyze(k3_opt_spec(512, 512, 512, k=3), GridSampler(None))
    q = jax.random.normal(jax.random.key(4), (512, 512), jnp.float32)
    am = jax.random.normal(jax.random.key(5), (512, 512), jnp.float32)
    wg0 = _time(lambda: ops.gramschm_k3(q, am, k=3, naive=True))
    wg1 = _time(lambda: ops.gramschm_k3(q.T, am, k=3, naive=False))
    rows.append(("gramschm_k3", hm_g0.sector_transactions(),
                 hm_g1.sector_transactions(), 23.18, wg0, wg1))

    # GPUMD naive RMW -> scratch-accumulated (not in paper Table III:
    # "requires domain experts"; our TPU-native fix, reported forcompleteness)
    cells_np = rng.integers(0, 2048, size=65536).astype(np.int64)
    hm_h0 = analyze(hist_naive_spec(65536, 2048), GridSampler(None),
                    dynamic_context={"cells": cells_np})
    hm_h1 = analyze(hist_opt2_spec(65536, 2048), GridSampler(None))
    cells = jnp.asarray(cells_np, jnp.int32)
    wh0 = _time(lambda: ops.histogram(cells, 2048, naive=True))
    wh1 = _time(lambda: ops.histogram(cells, 2048, naive=False))
    rows.append(("gpumd_cells", hm_h0, hm_h1, None, wh0, wh1))

    for name, before, after, paper, wb, wa in rows:
        tx_b = before if isinstance(before, (int, float)) else before.sector_transactions()
        tx_a = after if isinstance(after, (int, float)) else after.sector_transactions()
        speed = 100.0 * (tx_b / max(tx_a, 1) - 1.0)
        print(f"{name},{tx_b},{tx_a},{speed:.1f}%,"
              f"{paper if paper is not None else '-'}%,"
              f"{wb if wb is not None else '-'},{wa if wa is not None else '-'}")
        out.append((f"speedup_{name}", 0.0,
                    f"modeled +{speed:.0f}% vs paper +{paper}%"))
    return out


if __name__ == "__main__":
    run()
